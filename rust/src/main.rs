//! `fgmp` — the L3 coordinator CLI.
//!
//! Subcommands map onto the paper's experiments:
//!   * `quantize` — run the offline weight pipeline, report fractions/memory
//!   * `eval`     — perplexity of one configuration
//!   * `sweep`    — ratio/policy sweeps (Figs. 1/5/6/10 engines)
//!   * `tasks`    — downstream suites (Tables 2–3)
//!   * `hwsim`    — datapath energy/area/memory reports (Figs. 8/9, Table 4)
//!   * `serve`    — start the async serving coordinator demo
//!   * `report`   — precision-assignment visualization (Fig. 2b)
//!   * `bench`    — hotpath + forward benchmarks, emitted as
//!     machine-readable `BENCH_<name>.json` (the CI perf gate's input)

use fgmp::eval::sweep::format_rows;
use fgmp::eval::{run_sweep, Evaluator};
use fgmp::hwsim::area::AreaModel;
use fgmp::hwsim::energy::EnergyModel;
use fgmp::hwsim::memory::weight_memory_report;
use fgmp::io::synth;
use fgmp::model::{KvPrecision, ModelArtifacts, QuantConfig, QuantizedModel, RatioSpec};
use fgmp::policy::{Policy, ThresholdMode};
use fgmp::quant::Precision;
use fgmp::runtime::{EngineOptions, ExecSpec, GraphKind, Runtime};
use fgmp::Result;

/// Hand-rolled CLI (offline build: no clap; DESIGN.md SSDeps).
///
///   fgmp [--artifacts DIR] [--model NAME] <cmd> [flags]
struct Cli {
    artifacts: String,
    model: String,
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

const USAGE: &str = "\
fgmp — FGMP mixed-precision quantization coordinator

USAGE: fgmp [--artifacts DIR] [--model NAME] <command> [--flag value ...]

COMMANDS
  synth      [--seed 42]         build deterministic synthetic artifacts
  quantize   --fp4 0.7 --policy fisher|qe|oe [--no-clip] [--local-threshold]
  eval       --fp4 0.7 --policy P [--no-clip] [--local-threshold] --batches 16
  sweep      --fp4 0.9,0.8,0.7,0.5,0.3,0.1 --policy P [--no-clip] [--local-threshold] --batches 8
             [--spec k [--tokens 24]]
             with --spec k, sweeps speculative accept rate instead: each
             Fisher operating point decodes through the self-speculative
             engine (all-NVFP4 draft view) and reports the fraction of
             drafted tokens the target accepted
  tasks      --fp4 0.9,0.7 --max-items 64
  hwsim
  report     --linear blk0.fc1 --fp4 0.9 --rows 24
  serve      --fp4 0.7 --requests 64 [--gen 8] [--gen-tokens 16]
             [--kv fp16|fp8] [--decode-batch 8] [--kv-pages N]
             [--attn-ppu T] [--workers N] [--spec k] [--prefix-share]
             [--shared-prefix P] [--prefix-tokens 32] [--suffix-tokens 8]
             [--deadline-ms D] [--promote-after-ms 250]
             score + generate traffic through the coordinator: scoring
             batches the one-shot graph, generation runs the KV-cached
             continuous-batching decode loop over a paged KV arena
             (--kv picks the cache precision, --decode-batch its
             occupancy cap, --kv-pages the page-pool capacity; admits
             the pool cannot hold yet are deferred, not failed;
             --attn-ppu runs the FGMP PPU over attention inputs at
             impact threshold T and prices KV reads at the realized mix;
             --workers N > 1 serves over the tensor-parallel sharded
             engine — streams stay bit-identical to one worker;
             --spec k >= 2 runs self-speculative decoding: k-1 tokens
             drafted per round through the all-NVFP4 draft view of the
             same packed weights, verified in one batched pass —
             streams stay bit-exact and the accept rate is reported;
             --prefix-share turns on the copy-on-write prefix index:
             sessions whose prompts share whole 16-token pages map them
             by reference and prefill only the divergent suffix;
             --shared-prefix P > 0 draws generation prompts from the
             synthetic shared-prefix workload — P distinct system
             prompts of --prefix-tokens tokens, each request adding its
             own --suffix-tokens user turn — so the report shows a
             sharing factor > 1 and the admission budget stretches the
             same pool over more live sessions;
             --deadline-ms D cancels generation requests not finished
             within D ms of submission with a typed DeadlineExceeded;
             --promote-after-ms bounds deferred-queue starvation: young
             deferred heads may be bypassed by later requests that fit,
             an aged head turns admission strictly FIFO and preempts
             the youngest live session — preempted requests park with
             exponential backoff and resume bit-exact; 0 disables)
  generate   --prompt-len 16 --tokens 32 [--sessions 4] [--kv fp16|fp8]
             [--kv-pages N] [--attn-ppu T] [--workers N] [--spec k]
             [--prefix-share]
             drive the stateful engine directly: prefill all sessions
             as one batched forward over corpus prompts, decode them
             batched, print tokens + decode throughput + pool occupancy
             (--workers N > 1 decodes on the sharded engine; --spec k
             decodes speculatively off the all-NVFP4 draft view)
  bench      [--out .] [--name hotpath] [--budget-ms 300] [--baseline FILE]
             [--filter substr]
             run blocked-vs-scalar kernel + forward + decode benchmarks,
             write BENCH_<name>.json; with --baseline, exit non-zero on
             any >2x throughput regression (the CI perf gate); --filter
             runs only benches whose name contains substr

Commands that need artifacts synthesize them on first use when the model
directory is missing (hermetic default). Point --artifacts at a directory
produced by the Python pipeline to evaluate real exports instead.
";

impl Cli {
    fn parse() -> Result<Cli> {
        let mut args = std::env::args().skip(1).peekable();
        let mut artifacts = "artifacts".to_string();
        let mut model = "tiny-llama".to_string();
        let mut cmd = String::new();
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = args.next() {
            match a.as_str() {
                "--artifacts" => artifacts = args.next().unwrap_or_default(),
                "--model" => model = args.next().unwrap_or_default(),
                "-h" | "--help" => {
                    println!("{USAGE}");
                    std::process::exit(0);
                }
                f if f.starts_with("--") => {
                    let key = f.trim_start_matches("--").replace('-', "_");
                    // boolean flags take no value
                    let boolean =
                        matches!(key.as_str(), "no_clip" | "local_threshold" | "prefix_share");
                    let val = if boolean {
                        "true".to_string()
                    } else {
                        args.next().ok_or_else(|| anyhow::anyhow!("missing value for {f}"))?
                    };
                    flags.insert(key, val);
                }
                c if cmd.is_empty() => cmd = c.to_string(),
                other => anyhow::bail!("unexpected argument '{other}'\n{USAGE}"),
            }
        }
        if cmd.is_empty() {
            anyhow::bail!("no command given\n{USAGE}");
        }
        Ok(Cli { artifacts, model, cmd, flags })
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
    fn bool(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
    fn opt_usize(&self, key: &str) -> Option<usize> {
        self.flags.get(key).and_then(|v| v.parse().ok())
    }
    fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            Some(v) => v.split(',').filter_map(|x| x.parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

/// Engine-facing options `serve` and `generate` share, parsed once from
/// the same flags (`--kv`, `--kv-pages`, `--attn-ppu`, `--decode-batch`,
/// `--workers`, `--spec`) instead of per-command duplicates.
struct EngineCliOpts {
    kv: KvPrecision,
    kv_pages: Option<usize>,
    attn_ppu: Option<f32>,
    decode_batch: usize,
    workers: usize,
    spec: Option<usize>,
    prefix: bool,
}

impl EngineCliOpts {
    fn parse(cli: &Cli) -> Result<EngineCliOpts> {
        let spec = cli.opt_usize("spec");
        if let Some(k) = spec {
            anyhow::ensure!(k >= 2, "--spec k must be >= 2 (a round drafts k-1 tokens)");
        }
        Ok(EngineCliOpts {
            kv: KvPrecision::parse(&cli.str("kv", "fp16"))?,
            kv_pages: cli.opt_usize("kv_pages"),
            attn_ppu: cli.flags.get("attn_ppu").and_then(|v| v.parse::<f32>().ok()),
            decode_batch: cli.usize("decode_batch", 8),
            workers: cli.usize("workers", 1).max(1),
            spec,
            prefix: cli.bool("prefix_share"),
        })
    }

    /// The single flags → [`EngineOptions`] path. `workers > 1` makes the
    /// engine builder return the tensor-parallel sharded engine; `spec`
    /// wraps whichever engine it returns in the speculative decoder.
    fn to_engine_options(&self) -> EngineOptions {
        EngineOptions::default()
            .kv(self.kv)
            .pages(self.kv_pages)
            .attn(self.attn_ppu)
            .workers(self.workers)
            .spec(self.spec)
            .prefix_share(self.prefix)
    }
}

fn parse_policy(s: &str) -> Policy {
    match s {
        "qe" => Policy::QuantError,
        "oe" => Policy::OutputError,
        _ => Policy::Fisher,
    }
}

fn mk_config(fp4: f64, policy: &str, no_clip: bool, local: bool) -> QuantConfig {
    QuantConfig {
        ratio: if fp4 >= 1.0 {
            RatioSpec::AllFp4
        } else if fp4 <= 0.0 {
            RatioSpec::AllFp8
        } else {
            RatioSpec::Fp4Fraction(fp4)
        },
        policy: parse_policy(policy),
        threshold_mode: if local { ThresholdMode::Local } else { ThresholdMode::Global },
        sw_clip: !no_clip,
    }
}

/// Synthesize artifacts for the selected model when absent (and say so).
fn ensure_artifacts(cli: &Cli) -> Result<()> {
    let seed = cli.usize("seed", 42) as u64;
    let dir = std::path::Path::new(&cli.artifacts);
    if synth::ensure_model(dir, &cli.model, seed)? {
        println!(
            "(synthesized artifacts for {} under {} — seed {seed})",
            cli.model, cli.artifacts
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let cli = Cli::parse()?;
    // Only provision artifacts for commands that need them — and only after
    // the command name is known-good (a typo must not cost a synth run).
    if matches!(
        cli.cmd.as_str(),
        "quantize" | "eval" | "sweep" | "tasks" | "report" | "serve" | "generate"
    ) {
        ensure_artifacts(&cli)?;
    }
    match cli.cmd.as_str() {
        "synth" => {
            let seed = cli.usize("seed", 42) as u64;
            let dir = std::path::Path::new(&cli.artifacts);
            let wrote = synth::ensure_model(dir, &cli.model, seed)?;
            println!(
                "{} artifacts for {} under {} (seed {seed})",
                if wrote { "built" } else { "kept existing" },
                cli.model,
                cli.artifacts
            );
        }
        "quantize" => {
            let arts = ModelArtifacts::load(format!("{}/{}", cli.artifacts, cli.model))?;
            let cfg = mk_config(cli.f64("fp4", 0.7), &cli.str("policy", "fisher"),
                                cli.bool("no_clip"), cli.bool("local_threshold"));
            let t0 = std::time::Instant::now();
            let qm = QuantizedModel::quantize(&arts, &cfg)?;
            let w8 = qm.weight_fp8_fraction();
            let (fp8m, fgmpm, savings) =
                weight_memory_report(arts.manifest.quantized_elements(), w8);
            println!("model         : {}", cli.model);
            println!("config        : {}", cfg.label());
            println!("weight FP8    : {:.2}% of blocks", w8 * 100.0);
            println!("packed bits/w : {:.3}", fgmpm.bits_per_element());
            println!("memory        : {:.3} MiB (FP8 baseline {:.3} MiB, save {:.1}%)",
                     fgmpm.total_mib(), fp8m.total_mib(), savings * 100.0);
            let wm = qm.weight_memory();
            println!("resident exec : {:.3} MiB packed vs {:.3} MiB f32 ({:.1}% smaller — the \
                      kernels run off these bytes)",
                     wm.packed_bytes as f64 / (1 << 20) as f64,
                     wm.f32_equiv_bytes as f64 / (1 << 20) as f64,
                     wm.saving_vs_f32() * 100.0);
            println!("quantize time : {:?}", t0.elapsed());
            for l in qm.linears.iter().take(4) {
                println!("  {:<16} fp8 {:>6.2}%", l.name, l.packed.fp8_fraction() * 100.0);
            }
        }
        "eval" => {
            let rt = Runtime::cpu()?;
            let ev = Evaluator::load(&rt, &cli.artifacts, &cli.model)?;
            let cfg = mk_config(cli.f64("fp4", 0.7), &cli.str("policy", "fisher"),
                                cli.bool("no_clip"), cli.bool("local_threshold"));
            let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
            let rep = ev.perplexity(&cfg, Some(&qm), cli.usize("batches", 16))?;
            println!("{}: ppl {:.4} over {} tokens (act fp8 {:.1}%, weight fp8 {:.1}%)",
                     cfg.label(), rep.ppl, rep.tokens,
                     rep.mean_act_fp8() * 100.0, qm.weight_fp8_fraction() * 100.0);
        }
        "sweep" => {
            let rt = Runtime::cpu()?;
            let ev = Evaluator::load(&rt, &cli.artifacts, &cli.model)?;
            if let Some(k) = cli.opt_usize("spec") {
                anyhow::ensure!(k >= 2, "--spec k must be >= 2 (a round drafts k-1 tokens)");
                let rows = fgmp::eval::sweep::run_accept_sweep(
                    &rt,
                    &ev,
                    &cli.artifacts,
                    &cli.model,
                    &cli.f64_list("fp4", &[0.9, 0.7, 0.5, 0.3, 0.1]),
                    k,
                    cli.usize("tokens", 24),
                )?;
                print!("{}", fgmp::eval::sweep::format_accept_rows(k, &rows));
                return Ok(());
            }
            let mut configs = vec![
                QuantConfig { ratio: RatioSpec::Bf16, ..QuantConfig::fgmp(0.0) },
                QuantConfig::all_fp8(),
            ];
            for f in cli.f64_list("fp4", &[0.9, 0.8, 0.7, 0.5, 0.3, 0.1]) {
                configs.push(mk_config(f, &cli.str("policy", "fisher"),
                                       cli.bool("no_clip"), cli.bool("local_threshold")));
            }
            configs.push(QuantConfig::all_fp4());
            let rows = run_sweep(&ev, &configs, cli.usize("batches", 8))?;
            print!("{}", format_rows(&rows));
        }
        "tasks" => {
            cmd_tasks(&cli, &cli.f64_list("fp4", &[0.9, 0.7]), cli.usize("max_items", 64))?;
        }
        "hwsim" => {
            let em = EnergyModel::default();
            let am = AreaModel::default();
            println!("== datapath energy (pJ / 16-wide VMAC) ==");
            println!("FP8x8 {:.3}  FP4x4 {:.3}  FP4w/8a {:.3}  FP8w/4a {:.3}  mux-tax {:.3}",
                     em.e_fp8, em.e_fp4, em.e_fp4w_fp8a, em.e_fp8w_fp4a, em.e_mux_tax);
            println!("== area (um^2, Table 4) ==");
            println!("FP8 {:.0}  NVFP4 {:.0}  FP8/NVFP4 {:.0}  NVFP4/FP8 {:.0}  FGMP {:.0}  PPU {:.0}",
                     am.fp8_datapath, am.nvfp4_datapath, am.fp8_nvfp4_datapath,
                     am.nvfp4_fp8_datapath, am.fgmp_datapath, am.fgmp_ppu);
            println!("overhead vs FP8: {:.2}x  vs coarse MP: {:.2}x  PPU/datapath: {:.0}%",
                     am.overhead_vs_fp8(), am.overhead_vs_coarse(), am.ppu_overhead() * 100.0);
        }
        "report" => {
            let arts = ModelArtifacts::load(format!("{}/{}", cli.artifacts, cli.model))?;
            let cfg = QuantConfig::fgmp(cli.f64("fp4", 0.9));
            let qm = QuantizedModel::quantize(&arts, &cfg)?;
            let linear = cli.str("linear", "blk0.fc1");
            let l = qm
                .linears
                .iter()
                .find(|l| l.name == linear)
                .ok_or_else(|| anyhow::anyhow!("no linear named {linear}"))?;
            let bpr = l.assignment.blocks_per_row;
            let rows = cli.usize("rows", 24);
            println!("precision map of {linear} (first {rows} output channels; '#'=FP8, '.'=FP4):");
            for r in 0..rows {
                let row: String = (0..bpr)
                    .map(|b| match l.assignment.precision[r * bpr + b] {
                        Precision::Fp8 => '#',
                        Precision::Fp4 => '.',
                    })
                    .collect();
                println!("  {row}");
            }
            println!("layer fp8 fraction: {:.2}%", l.packed.fp8_fraction() * 100.0);
        }
        "serve" => {
            cmd_serve(&cli, cli.f64("fp4", 0.7), cli.usize("requests", 64))?;
        }
        "generate" => {
            cmd_generate(&cli)?;
        }
        "bench" => {
            cmd_bench(&cli)?;
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

fn cmd_tasks(cli: &Cli, fp4: &[f64], max_items: usize) -> Result<()> {
    use fgmp::eval::tasks::{score_suite, TaskSuite};
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &cli.artifacts, &cli.model)?;
    let suites: Vec<TaskSuite> = std::fs::read_dir(format!("{}/tasks", cli.artifacts))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
        .map(|e| TaskSuite::load(e.path()))
        .collect::<Result<_>>()?;

    let mut configs = vec![QuantConfig::all_fp8(), QuantConfig::all_fp4()];
    for f in fp4 {
        configs.push(QuantConfig::fgmp(*f));
    }
    println!("{:<16} {}", "suite",
             configs.iter().map(|c| format!("{:>12}", c.ratio.label())).collect::<String>());
    for suite in &suites {
        print!("{:<16}", suite.name);
        for cfg in &configs {
            let qm = QuantizedModel::quantize(&ev.arts, cfg)?;
            let tail = ev.quant_arg_tail(cfg, &qm)?;
            let acc = score_suite(&ev.fwd_quant, &tail, suite, ev.batch, ev.seq, max_items)?;
            print!("{:>12.3}", acc);
        }
        println!();
    }
    Ok(())
}

/// `fgmp bench`: the shared kernel + pipeline benchmark suite
/// (`fgmp::benchsuite` — same workloads `cargo bench --bench hotpath`
/// runs), collected into `BENCH_<name>.json`. With `--baseline FILE`,
/// acts as the CI perf gate: exits non-zero when any bench regresses by
/// more than 2x against the checked-in baseline, or a derived speedup
/// falls below its floor.
fn cmd_bench(cli: &Cli) -> Result<()> {
    use fgmp::benchsuite::run_benches;
    use fgmp::util::bench::{budget_from_env, BenchSuite};
    use std::time::Duration;

    let budget = cli
        .flags
        .get("budget_ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or_else(|| budget_from_env(300));
    let name = cli.str("name", "hotpath");
    let out_dir = cli.str("out", ".");
    let filter = cli.flags.get("filter").cloned();
    let mut suite = BenchSuite::new(&name);
    match &filter {
        Some(f) => println!("== fgmp bench: suite '{name}', budget {budget:?}, filter '{f}' =="),
        None => println!("== fgmp bench: suite '{name}', budget {budget:?} =="),
    }

    run_benches(&mut suite, budget, filter.as_deref());
    suite.set_meta("budget_ms", budget.as_millis().to_string());

    let path = suite.write(&out_dir)?;
    println!("wrote {}", path.display());

    if let Some(bp) = cli.flags.get("baseline") {
        // Under --filter, gate against the matching slice of the baseline:
        // the groups that ran are exactly the ones producing names the
        // same substring matches, so the sliced gate stays meaningful
        // without failing on benches the filter deliberately skipped.
        let mut baseline = BenchSuite::load(bp)?;
        if let Some(sub) = filter.as_deref() {
            baseline = baseline.filtered(sub);
        }
        let fails = suite.check_regressions(&baseline, 2.0);
        if fails.is_empty() {
            println!(
                "perf gate: OK ({} baseline benches, {} derived floors{})",
                baseline.results.len(),
                baseline.derived.len(),
                if filter.is_some() { ", filtered" } else { "" }
            );
        } else {
            for f in &fails {
                eprintln!("perf gate FAIL: {f}");
            }
            anyhow::bail!("{} perf regression(s) vs baseline {bp}", fails.len());
        }
    }
    Ok(())
}

fn cmd_serve(cli: &Cli, fp4: f64, requests: usize) -> Result<()> {
    use fgmp::coordinator::{
        kv_dims_from_profiles, BatchPolicy, Request, RequestKind, Server, ServerConfig,
    };
    use fgmp::hwsim::kvcache::kv_cache_bits;

    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &cli.artifacts, &cli.model)?;
    let cfg = QuantConfig::fgmp(fp4);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
    let fwd_tail = ev.quant_arg_tail(&cfg, &qm)?;
    // logits graph: same tail but no mask arg (tokens, params, aw, thr).
    let fwd_spec = ExecSpec::new(&cli.artifacts, &cli.model, GraphKind::FwdQuant);
    let logits_spec = ExecSpec::new(&cli.artifacts, &cli.model, GraphKind::LogitsQuant);
    let logits_tail = fwd_tail.clone();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let eopts = EngineCliOpts::parse(cli)?;
    let kv_precision = eopts.kv;
    let gen_requests = cli.usize("gen", 8);
    let gen_tokens = cli.usize("gen_tokens", 16);
    let kv_dims = kv_dims_from_profiles(&shapes)?;

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy::default(),
        layer_shapes: shapes,
        queue_depth: 256,
        kv_precision,
        decode_batch: eopts.decode_batch,
        kv_pages: eopts.kv_pages,
        energy: fgmp::hwsim::energy::EnergyModel::default(),
        attn_threshold: eopts.attn_ppu,
        workers: eopts.workers,
        spec: eopts.spec,
        prefix_share: eopts.prefix,
        deadline_ms: cli.flags.get("deadline_ms").and_then(|v| v.parse().ok()),
        promote_after_ms: cli.usize("promote_after_ms", 250) as u64,
    };
    // --shared-prefix P swaps the generation prompts for the synthetic
    // shared-prefix workload: P system prompts reused round-robin, each
    // request adding its own short user suffix. With --prefix-share this
    // is the traffic that exercises the COW prefix index.
    let shared_prefixes = cli.usize("shared_prefix", 0);
    let gen_prompts: Vec<Vec<i32>> = if shared_prefixes > 0 {
        synth::shared_prefix_prompts(
            cli.usize("seed", 42) as u64,
            gen_requests,
            shared_prefixes,
            cli.usize("prefix_tokens", 32),
            cli.usize("suffix_tokens", 8),
        )
    } else {
        Vec::new()
    };
    let windows = ev.eval_windows(requests.div_ceil(ev.batch));
    let seq = ev.seq;
    let server = Server::start(scfg, fwd_spec, fwd_tail, logits_spec, logits_tail)?;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut gen_rxs = Vec::new();
    let mut id = 0u64;
    for (wi, w) in windows.iter().enumerate() {
        for row in w.chunks_exact(seq) {
            let (req, rx) = Request::new(
                id,
                RequestKind::Score { tokens: row.to_vec(), mask: vec![1.0; seq] },
            );
            id += 1;
            server.router.submit(req)?;
            rxs.push(rx);
            // Interleave generation traffic: one prompt per few score rows.
            if gen_rxs.len() < gen_requests && wi % 2 == 0 {
                let prompt = match gen_prompts.get(gen_rxs.len()) {
                    Some(p) => p.clone(),
                    None => row[..row.len().min(8)].to_vec(),
                };
                let (req, rx) =
                    Request::new(id, RequestKind::Generate { prompt, n_tokens: gen_tokens });
                id += 1;
                server.router.submit(req)?;
                gen_rxs.push(rx);
            }
        }
    }
    // Top up if the window loop produced fewer gen requests than asked.
    while gen_rxs.len() < gen_requests {
        let prompt = gen_prompts.get(gen_rxs.len()).cloned().unwrap_or_else(|| {
            windows.first().map(|w| w[..8.min(w.len())].to_vec()).unwrap_or_else(|| vec![0])
        });
        let (req, rx) = Request::new(id, RequestKind::Generate { prompt, n_tokens: gen_tokens });
        id += 1;
        server.router.submit(req)?;
        gen_rxs.push(rx);
    }
    let mut nll = 0.0;
    let mut toks = 0.0;
    for rx in rxs {
        if let Ok(resp) = rx.recv() {
            if let Some((s, n)) = resp.nll {
                nll += s;
                toks += n;
            }
        }
    }
    let mut gen_toks = 0usize;
    for rx in &gen_rxs {
        if let Ok(resp) = rx.recv() {
            if let Some(g) = resp.generated {
                gen_toks += g.len();
            }
        }
    }
    let wall = t0.elapsed();
    let snap = server.metrics.snapshot();
    println!("served {} score rows in {:.2}s ({:.1} tok/s)", snap.requests,
             wall.as_secs_f64(), toks / wall.as_secs_f64());
    println!("ppl {:.4}  p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms  fill {:.0}%",
             (nll / toks).exp(), snap.p50_ms, snap.p95_ms, snap.p99_ms,
             snap.mean_batch_fill * 100.0);
    println!("gen: {gen_toks} tokens / {} reqs  {:.1} tok/s decode  ttft p50 {:.1}ms p95 {:.1}ms",
             gen_rxs.len(), snap.decode_tok_per_s, snap.ttft_p50_ms, snap.ttft_p95_ms);
    println!("decode: {} steps  occupancy {:.2} ({:.0}% of {})  workers {}",
             snap.decode_steps, snap.mean_decode_occupancy, snap.decode_fill * 100.0,
             eopts.decode_batch, eopts.workers);
    let kv_bytes_per_tok =
        kv_cache_bits(&kv_dims, 1, kv_precision.bits_per_value()) as f64 / 8.0;
    println!("kv: {} cache, {:.0} B/token ({:.0} B/token at fp16)",
             kv_precision.label(), kv_bytes_per_tok,
             kv_cache_bits(&kv_dims, 1, 16.0) as f64 / 8.0);
    if snap.kv_read_bits_per_value > 0.0 {
        println!("kv reads: {:.2} bits/value stored precision (token-weighted over decode)",
                 snap.kv_read_bits_per_value);
    }
    let wm = qm.weight_memory();
    println!("exec weights: {:.3} MiB packed in-engine ({} linears) vs {:.3} MiB f32 — {:.1}% smaller",
             wm.packed_bytes as f64 / (1 << 20) as f64, wm.linears,
             wm.f32_equiv_bytes as f64 / (1 << 20) as f64, wm.saving_vs_f32() * 100.0);
    if let Some(k) = eopts.spec {
        // The draft view is a second resident copy of the packed linears,
        // every block at the uniform NVFP4 stride.
        let draft_bytes: usize =
            qm.linears.iter().map(|l| l.packed.all_fp4_resident_bytes()).sum();
        println!("spec: k={k}  accept rate {:.1}% ({} accepted / {} drafted)  \
                  draft view {:.3} MiB all-NVFP4 resident  cooldowns {}",
                 snap.spec_accept_rate * 100.0, snap.spec_accepted, snap.spec_drafted,
                 draft_bytes as f64 / (1 << 20) as f64, snap.spec_cooldowns);
    }
    if snap.kv_pool_pages > 0 {
        println!("kv pool: {} pages  peak {}  occupancy {:.0}%  page fill {:.0}%  deferred {}",
                 snap.kv_pool_pages, snap.kv_pool_peak_pages,
                 snap.kv_pool_occupancy * 100.0, snap.kv_page_fill * 100.0,
                 snap.deferred_admissions);
        println!("kv sharing: {:.2}x logical/unique  deduped {:.3} MiB peak{}",
                 snap.kv_sharing_factor, snap.kv_deduped_mib_peak,
                 if eopts.prefix { "  (prefix sharing on)" } else { "" });
    }
    if snap.preemptions > 0
        || snap.deadline_rejections > 0
        || snap.batch_retries > 0
        || snap.worker_failures > 0
        || snap.faults_injected > 0
    {
        println!("robustness: {} preempted ({} resumed)  {} deadline-rejected  \
                  {} batch retries  {} worker failures  {} faults injected",
                 snap.preemptions, snap.preempt_resumes, snap.deadline_rejections,
                 snap.batch_retries, snap.worker_failures, snap.faults_injected);
    }
    println!("sim energy {:.3} mJ vs FP8 {:.3} mJ  (savings {:.1}%, incl. KV traffic)",
             snap.energy_j * 1e3, snap.energy_fp8_j * 1e3, snap.energy_savings * 100.0);
    server.shutdown();
    Ok(())
}

/// `fgmp generate`: drive the stateful engine directly — prefill one or
/// more sessions from corpus windows, decode them batched, and report
/// tokens + decode throughput. The single-process view of what the `serve`
/// coordinator does continuously. Drives whatever
/// [`fgmp::runtime::build_engine`] returns for the flags — the
/// single-worker [`fgmp::runtime::Engine`], or the tensor-parallel
/// [`fgmp::runtime::ShardedEngine`] under `--workers N > 1` — through the
/// [`fgmp::runtime::InferenceEngine`] surface.
fn cmd_generate(cli: &Cli) -> Result<()> {
    use fgmp::runtime::build_engine;

    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &cli.artifacts, &cli.model)?;
    let cfg = QuantConfig::fgmp(cli.f64("fp4", 0.7));
    let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
    let tail = ev.quant_arg_tail(&cfg, &qm)?;
    let spec = ExecSpec::new(&cli.artifacts, &cli.model, GraphKind::LogitsQuant);
    let eopts = EngineCliOpts::parse(cli)?;
    let engine = build_engine(&rt, &spec, tail, eopts.to_engine_options())?;

    let prompt_len = cli.usize("prompt_len", 16).clamp(1, ev.test_stream.len().max(1));
    let n_tokens = cli.usize("tokens", 32);
    let n_sessions = cli.usize("sessions", 4).max(1);

    let t0 = std::time::Instant::now();
    let mut prompts = Vec::with_capacity(n_sessions);
    for i in 0..n_sessions {
        let off = (i * prompt_len) % ev.test_stream.len().saturating_sub(prompt_len).max(1);
        prompts.push(ev.test_stream[off..off + prompt_len].to_vec());
    }
    // All sessions prefill as one batched forward over the blocked kernels.
    let mut sessions = engine.prefill_batch(&prompts)?;
    let prefill_t = t0.elapsed();

    let mut produced: Vec<Vec<i32>> = sessions.iter().map(|s| vec![s.next_token()]).collect();
    let t1 = std::time::Instant::now();
    let mut steps = 0usize;
    while produced.iter().any(|p| p.len() < n_tokens) {
        // Step only the sessions still short of their budget (continuous
        // retirement, single-process edition).
        let idx: Vec<usize> =
            (0..sessions.len()).filter(|&i| produced[i].len() < n_tokens).collect();
        let mut stepping: Vec<&mut fgmp::runtime::Session> = sessions
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| produced[*i].len() < n_tokens)
            .map(|(_, s)| s)
            .collect();
        engine.decode_step(&mut stepping)?;
        for (slot, &i) in idx.iter().enumerate() {
            // Speculative rounds accept extra tokens beyond one-per-step;
            // they precede the current logits' next_token in the stream.
            produced[i].extend(stepping[slot].take_accepted());
            produced[i].push(stepping[slot].next_token());
        }
        steps += 1;
    }
    let decode_t = t1.elapsed();

    let total: usize = produced.iter().map(|p| p.len().min(n_tokens)).sum();
    println!(
        "engine: {} path, kv {}, {} worker(s)  |  {n_sessions} sessions, \
         prompt {prompt_len}, {n_tokens} tokens each",
        if engine.is_cached() { "cached" } else { "windowed-recompute" },
        engine.kv_precision().label(),
        engine.workers(),
    );
    let wm = engine.weight_memory();
    if wm.linears > 0 {
        println!(
            "weights: {:.3} MiB resident packed ({} linears) vs {:.3} MiB f32 — {:.1}% smaller",
            wm.packed_bytes as f64 / (1 << 20) as f64,
            wm.linears,
            wm.f32_equiv_bytes as f64 / (1 << 20) as f64,
            wm.saving_vs_f32() * 100.0
        );
    }
    if let Some(k) = engine.spec_k() {
        let drafted: u64 = sessions.iter().map(|s| s.spec_drafted_total).sum();
        let accepted: u64 = sessions.iter().map(|s| s.spec_accepted_total).sum();
        let rate = if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
        println!(
            "spec: k={k}  accept rate {:.1}% ({accepted} accepted / {drafted} drafted)  \
             draft view {:.3} MiB all-NVFP4 resident",
            rate * 100.0,
            engine.spec_draft_bytes().unwrap_or(0) as f64 / (1 << 20) as f64
        );
    }
    for (i, p) in produced.iter().enumerate() {
        let shown: Vec<String> = p[..p.len().min(n_tokens)].iter().map(|t| t.to_string()).collect();
        println!("  s{i} [{}...] -> {}", prompts[i][..4.min(prompts[i].len())]
                 .iter().map(|t| t.to_string()).collect::<Vec<_>>().join(","),
                 shown.join(" "));
    }
    let kv_bits: u64 = sessions.iter().map(|s| s.kv_bits()).sum();
    let kv_pages: usize = sessions.iter().map(|s| s.kv_pages()).sum();
    println!("prefill {:.1}ms (batched)  decode {} steps in {:.1}ms  ({:.1} tok/s)",
             prefill_t.as_secs_f64() * 1e3, steps, decode_t.as_secs_f64() * 1e3,
             total as f64 / decode_t.as_secs_f64().max(1e-9));
    println!("kv held: {:.1} KiB across sessions ({kv_pages} pages)",
             kv_bits as f64 / 8.0 / 1024.0);
    if let Some(stats) = engine.pool_stats() {
        println!("kv pool: {}/{} pages in use (peak {}, {} tok/page, {} exhaustion events)",
                 stats.in_use_pages, stats.total_pages, stats.peak_in_use,
                 stats.page_tokens, stats.exhausted_events);
        println!("kv sharing: {:.2}x ({} logical over {} unique pages, {} COW copies)",
                 stats.sharing_factor(), stats.logical_pages, stats.in_use_pages,
                 stats.cow_copies);
    }
    if let Some(ps) = engine.prefix_stats() {
        println!("prefix index: {} pages held  {} hits / {} misses  {} tokens reused  \
                  {} evictions",
                 ps.pages_held, ps.hits, ps.misses, ps.tokens_reused, ps.evictions);
    }
    Ok(())
}
