//! Shared benchmark workloads for the hotpath kernels — one definition of
//! each named bench (shape, seed, derived metric), used by both the
//! `fgmp bench` CLI subcommand and `cargo bench --bench hotpath`, so the
//! two suites and the checked-in CI baseline (`ci/bench-baseline.json`)
//! cannot drift apart on names or workloads.

use std::time::Duration;

use crate::io::synth::SynthConfig;
use crate::model::forward::{
    fgmp_matmul, fgmp_matmul_packed, forward, forward_prefill, forward_prefill_batch,
    forward_step, forward_step_batch, Act, ModelArch, NormKind, Params, PosKind,
};
use crate::model::kv::{KvPrecision, KvState};
use crate::quant::fp8::quant_e4m3_slice;
use crate::quant::{
    nvfp4_roundtrip, quant_e4m3, sw_clip_tensor, FgmpTensor, PackedPanels, Precision,
};
use crate::util::bench::{bench, black_box, BenchResult, BenchSuite};
use crate::util::kernels::MatmulScratch;
use crate::util::{kernels, Rng};
use crate::BLOCK;

/// Canonical bench + derived-metric names. `ci/bench-baseline.json` gates
/// on these strings; the `baseline_gates_on_known_names` test pins the
/// baseline file to this list, so a rename is a conscious two-sided edit.
pub mod names {
    pub const MATMUL_SCALAR: &str = "matmul_scalar_256x512x1536";
    pub const MATMUL_BLOCKED: &str = "matmul_blocked_256x512x1536";
    pub const MATMUL_DEQUANT: &str = "matmul_dequant_256x512x1536";
    pub const MATMUL_PACKED: &str = "matmul_packed_256x512x1536";
    pub const MATMUL_T_SCALAR: &str = "matmul_t_scalar_256x512x256";
    pub const MATMUL_T_BLOCKED: &str = "matmul_t_blocked_256x512x256";
    pub const QUANT_E4M3_SCALAR: &str = "quant_e4m3_scalar_64k";
    pub const QUANT_E4M3_SLICE: &str = "quant_e4m3_slice_64k";
    pub const NVFP4_ROUNDTRIP: &str = "nvfp4_roundtrip_64k";
    pub const SW_CLIP: &str = "sw_clip_256x512";
    pub const FGMP_MATMUL: &str = "fgmp_matmul_256x512x1536";
    pub const FGMP_MATMUL_PACKED: &str = "fgmp_matmul_packed_256x512x1536";
    pub const FORWARD_D512: &str = "forward_d512_b1s32";
    pub const DECODE_RECOMPUTE: &str = "decode_recompute_d512_p16_g8";
    pub const DECODE_CACHED: &str = "decode_cached_d512_p16_g8";
    pub const DECODE_OCC1: &str = "decode_step_d512_occ1";
    pub const DECODE_OCC4: &str = "decode_step_d512_occ4";
    pub const DECODE_OCC8: &str = "decode_step_d512_occ8";
    pub const DECODE_OCC8_PAGED: &str = "decode_step_paged_d512_occ8";
    pub const DECODE_CHURN_PAGED: &str = "decode_paged_churn_d512";
    pub const PREFILL_SEQ: &str = "prefill_sequential_d512_p16x8";
    pub const PREFILL_BATCHED: &str = "prefill_batched_d512_p16x8";
    pub const DECODE_LONGCTX_FP16: &str = "decode_step_longctx_d512_w4k_fp16";
    pub const DECODE_LONGCTX_FP8: &str = "decode_step_longctx_d512_w4k_fp8";
    pub const DECODE_TP_W1: &str = "decode_step_tp_w1_d512_occ8";
    pub const DECODE_TP_W2: &str = "decode_step_tp_w2_d512_occ8";
    pub const DECODE_SPEC_PLAIN: &str = "decode_step_packed_d512_occ1";
    pub const DECODE_SPEC_ROUND: &str = "decode_spec_round_d512_occ1_k4";
    pub const SESSION_FORK_COPY: &str = "session_fork_copy_d512";
    pub const SESSION_FORK_COW: &str = "session_fork_cow_d512";
    /// One preempt/resume cycle at the page level: look the donated
    /// 112-token context up in the prefix trie, map its whole pages into a
    /// fresh session, and append the one-token suffix — the coordinator's
    /// resume fast path after a pressure preemption.
    pub const PREEMPT_RESUME: &str = "preempt_resume_d512";

    pub const SPEEDUP_MATMUL: &str = "speedup_matmul_d512";
    pub const SPEEDUP_MATMUL_T: &str = "speedup_matmul_t_d512";
    pub const SPEEDUP_QUANT: &str = "speedup_quant_e4m3";
    pub const SPEEDUP_DECODE: &str = "speedup_decode_cached_d512";
    pub const SPEEDUP_PREFILL_BATCHED: &str = "speedup_prefill_batched_d512";
    pub const RATIO_DECODE_PAGED: &str = "ratio_decode_paged_occ8_d512";
    /// Packed-kernel min-time throughput over the dequant-f32 kernel on
    /// the same quantized weight (≥ 0.9 floor: executing off the bits must
    /// not cost more than 10% even on the scalar build).
    pub const RATIO_MATMUL_PACKED: &str = "ratio_matmul_packed_d512";
    /// Fractional resident weight-memory saving of the packed execution
    /// tensor vs a dequantized f32 copy (≥ 0.30 floor).
    pub const WEIGHT_MEM_SAVING_PACKED: &str = "weight_mem_saving_packed_d512";
    /// FP16-step min time over FP8-step min time at the 4k window (≥ 0.7
    /// floor: reading stored E4M3 bytes through the in-register LUT must
    /// stay within ~1.4x of the f32 read path even on the scalar build).
    pub const RATIO_DECODE_LONGCTX_FP8: &str = "ratio_decode_longctx_fp8_d512";
    /// 2-worker sharded decode throughput over the single-worker batched
    /// step at the same occupancy-8 workload (≥ 1.15 floor: splitting the
    /// per-layer linears and attention heads across two threads must beat
    /// one worker by a sane margin despite the fork/join overhead).
    pub const SCALING_EFF_DECODE_W2: &str = "scaling_eff_decode_w2_d512";
    /// Tokens/s of the self-speculative decode round (fork + k−1 all-NVFP4
    /// draft steps + one k-row batched verify + rollback) over plain
    /// token-at-a-time FGMP decode at occupancy 1, on the draft-lossless
    /// lattice fixture where every round accepts all k−1 guesses (≥ 1.5
    /// floor at k = 4: drafting at half the weight-read bytes plus the
    /// batched verify's weight-reuse must beat stepping one token at a
    /// time).
    pub const SPEEDUP_DECODE_SPEC: &str = "speedup_decode_spec_occ1_d512";
    /// Resident bytes of the paper-mix (30% FP8) execution tensor over its
    /// all-NVFP4 draft view (≥ 1.15 floor: the draft view must stay a real
    /// weight-memory shrink, not a second full-size artifact).
    pub const DRAFT_VIEW_SHRINK: &str = "draft_view_shrink_d512";
    /// Deep-fork min time over COW-fork min time on a 112-token paged
    /// session (≥ 2.0 floor: a fork must be an O(page-table) refcount
    /// bump, not an O(tokens) arena copy — the win speculative drafts and
    /// session clones ride on).
    pub const SPEEDUP_FORK_COW: &str = "speedup_fork_cow_d512";
    /// Realized pool sharing factor (Σ refcounts / unique pages) with 64
    /// live sessions admitted through the prefix trie into a pool sized
    /// for 16 (≥ 2.0 floor: prefix sharing must actually multiply pool
    /// capacity, not just deduplicate a page or two).
    pub const SHARING_FACTOR_PREFIX: &str = "sharing_factor_prefix_d512";
    /// Fraction of the resume context a preempted-then-resumed session
    /// gets back from donated trie pages rather than recomputing (≥ 0.8
    /// floor: resuming must be a whole-page map plus a short suffix, not a
    /// hidden full re-prefill).
    pub const RESUME_REUSE_FRAC: &str = "resume_reuse_frac_d512";

    pub const ALL: [&str; 31] = [
        MATMUL_SCALAR,
        MATMUL_BLOCKED,
        MATMUL_DEQUANT,
        MATMUL_PACKED,
        MATMUL_T_SCALAR,
        MATMUL_T_BLOCKED,
        QUANT_E4M3_SCALAR,
        QUANT_E4M3_SLICE,
        NVFP4_ROUNDTRIP,
        SW_CLIP,
        FGMP_MATMUL,
        FGMP_MATMUL_PACKED,
        FORWARD_D512,
        DECODE_RECOMPUTE,
        DECODE_CACHED,
        DECODE_OCC1,
        DECODE_OCC4,
        DECODE_OCC8,
        DECODE_OCC8_PAGED,
        DECODE_CHURN_PAGED,
        PREFILL_SEQ,
        PREFILL_BATCHED,
        DECODE_LONGCTX_FP16,
        DECODE_LONGCTX_FP8,
        DECODE_TP_W1,
        DECODE_TP_W2,
        DECODE_SPEC_PLAIN,
        DECODE_SPEC_ROUND,
        SESSION_FORK_COPY,
        SESSION_FORK_COW,
        PREEMPT_RESUME,
    ];
    pub const ALL_DERIVED: [&str; 15] = [
        SPEEDUP_MATMUL,
        SPEEDUP_MATMUL_T,
        SPEEDUP_QUANT,
        SPEEDUP_DECODE,
        SPEEDUP_PREFILL_BATCHED,
        RATIO_DECODE_PAGED,
        RATIO_MATMUL_PACKED,
        WEIGHT_MEM_SAVING_PACKED,
        RATIO_DECODE_LONGCTX_FP8,
        SCALING_EFF_DECODE_W2,
        SPEEDUP_DECODE_SPEC,
        DRAFT_VIEW_SHRINK,
        SPEEDUP_FORK_COW,
        SHARING_FACTOR_PREFIX,
        RESUME_REUSE_FRAC,
    ];
}

/// One entry per bench function: group name, the function, the bench names
/// it pushes, and the derived metrics it records. This is the `--filter`
/// unit — pairs and ratios need their in-group siblings, so a filter
/// selects whole groups, and the registry is what guarantees a filtered
/// baseline slice (`BenchSuite::filtered` over the same substring) only
/// gates names the selected groups actually produce.
type BenchFn = fn(&mut BenchSuite, Duration);
pub const GROUPS: [(&str, BenchFn, &[&str], &[&str]); 7] = [
    (
        "kernel",
        kernel_benches,
        &[
            names::MATMUL_SCALAR,
            names::MATMUL_BLOCKED,
            names::MATMUL_DEQUANT,
            names::MATMUL_PACKED,
            names::MATMUL_T_SCALAR,
            names::MATMUL_T_BLOCKED,
            names::QUANT_E4M3_SCALAR,
            names::QUANT_E4M3_SLICE,
            names::NVFP4_ROUNDTRIP,
        ],
        &[
            names::SPEEDUP_MATMUL,
            names::RATIO_MATMUL_PACKED,
            names::WEIGHT_MEM_SAVING_PACKED,
            names::SPEEDUP_MATMUL_T,
            names::SPEEDUP_QUANT,
        ],
    ),
    (
        "pipeline",
        pipeline_benches,
        &[names::SW_CLIP, names::FGMP_MATMUL, names::FGMP_MATMUL_PACKED, names::FORWARD_D512],
        &[],
    ),
    (
        "decode",
        decode_benches,
        &[
            names::DECODE_RECOMPUTE,
            names::DECODE_CACHED,
            names::DECODE_OCC1,
            names::DECODE_OCC4,
            names::DECODE_OCC8,
            names::DECODE_OCC8_PAGED,
            names::DECODE_CHURN_PAGED,
            names::PREFILL_SEQ,
            names::PREFILL_BATCHED,
        ],
        &[names::SPEEDUP_DECODE, names::RATIO_DECODE_PAGED, names::SPEEDUP_PREFILL_BATCHED],
    ),
    (
        "longctx",
        longctx_benches,
        &[names::DECODE_LONGCTX_FP16, names::DECODE_LONGCTX_FP8],
        &[names::RATIO_DECODE_LONGCTX_FP8],
    ),
    (
        "sharded",
        sharded_benches,
        &[names::DECODE_TP_W1, names::DECODE_TP_W2],
        &[names::SCALING_EFF_DECODE_W2],
    ),
    (
        "spec",
        spec_benches,
        &[names::DECODE_SPEC_PLAIN, names::DECODE_SPEC_ROUND],
        &[names::SPEEDUP_DECODE_SPEC, names::DRAFT_VIEW_SHRINK],
    ),
    (
        "prefix",
        prefix_benches,
        &[names::SESSION_FORK_COPY, names::SESSION_FORK_COW, names::PREEMPT_RESUME],
        &[names::SPEEDUP_FORK_COW, names::SHARING_FACTOR_PREFIX, names::RESUME_REUSE_FRAC],
    ),
];

/// Does the group run under this filter? `None` runs everything; a
/// substring selects every group whose name, bench names, or derived
/// metric names contain it.
pub fn group_matches(
    filter: Option<&str>,
    group: &str,
    benches: &[&str],
    derived: &[&str],
) -> bool {
    match filter {
        None => true,
        Some(sub) => {
            group.contains(sub)
                || benches.iter().any(|n| n.contains(sub))
                || derived.iter().any(|n| n.contains(sub))
        }
    }
}

/// Run the whole suite — or, with a `--filter` substring, only the groups
/// it names. Skipped groups are announced so a filtered `BENCH_*.json` is
/// never mistaken for a full run, and the filter is recorded in the
/// suite's metadata.
pub fn run_benches(suite: &mut BenchSuite, budget: Duration, filter: Option<&str>) {
    for (group, f, benches, derived) in GROUPS {
        if group_matches(filter, group, benches, derived) {
            f(suite, budget);
        } else {
            println!("-- skipping group '{group}' ({} benches; filter)", benches.len());
        }
    }
    if let Some(sub) = filter {
        suite.set_meta("filter", sub);
    }
}

/// Print one result and add it to the suite.
pub fn keep(suite: &mut BenchSuite, r: BenchResult) {
    println!("{}", r.report());
    suite.push(r);
}

/// Record a scalar/fast pair plus the derived min-time speedup under `key`.
fn pair(suite: &mut BenchSuite, key: &str, scalar: BenchResult, fast: BenchResult) {
    let s = scalar.min.as_secs_f64() / fast.min.as_secs_f64().max(1e-12);
    println!("{}", scalar.report());
    println!("{}", fast.report());
    println!("  -> {key} {s:.2}x");
    suite.push(scalar);
    suite.push(fast);
    suite.derive(key, s);
}

/// Quantize a dense `(K, N)` weight to the paper's 30% FP8 / 70% NVFP4
/// block mix and return its k-panelized execution tensor plus the
/// dequantized f32 copy (the packed-vs-dequant bench inputs).
fn quantized_panels(w: &[f32], k: usize, n: usize) -> (PackedPanels, Vec<f32>) {
    let kb = k / BLOCK;
    let mut data_t = vec![0.0f32; k * n];
    for ki in 0..k {
        for ni in 0..n {
            data_t[ni * k + ki] = w[ki * n + ni];
        }
    }
    let prec: Vec<Precision> =
        (0..n * kb).map(|i| if i % 10 < 3 { Precision::Fp8 } else { Precision::Fp4 }).collect();
    let t = FgmpTensor::pack(&[n, k], &data_t, &prec, None);
    let panels = PackedPanels::from_tensor(&t, kernels::NR);
    let deq = panels.unpack_kn();
    (panels, deq)
}

/// Blocked-vs-scalar kernel comparisons at the d_model=512 shape class:
/// the dense matmul, the transposed (LM head) matmul, and the E4M3 slice
/// quantizer — each fast path against its same-workload scalar sibling —
/// plus the NVFP4 tensor round-trip.
pub fn kernel_benches(suite: &mut BenchSuite, budget: Duration) {
    let mut rng = Rng::new(42);

    // Dense matmul at the small-llama qkv shape.
    let (m, k, n) = (256usize, 512usize, 1536usize);
    let x = rng.normal_vec(m * k, 1.0);
    let w = rng.normal_vec(k * n, 0.05);
    let macs = (m * k * n) as u64;
    let scalar = bench(names::MATMUL_SCALAR, Some(macs), budget, || {
        kernels::matmul_scalar(black_box(&x), &w, m, k, n)
    });
    let fast = bench(names::MATMUL_BLOCKED, Some(macs), budget, || {
        kernels::matmul(black_box(&x), &w, m, k, n)
    });
    pair(suite, names::SPEEDUP_MATMUL, scalar, fast);

    // Packed vs dequant at the same shape: quantize the weight to the
    // paper's 30% FP8 / 70% NVFP4 mix, then multiply (a) the blocked f32
    // kernel over the dequantized copy — yesterday's execution path — vs
    // (b) the packed kernel decoding the same bits in-register. The weight
    // -memory saving of the packed resident form is recorded alongside.
    let (panels, deq) = quantized_panels(&w, k, n);
    let dequant = bench(names::MATMUL_DEQUANT, Some(macs), budget, || {
        kernels::matmul(black_box(&x), &deq, m, k, n)
    });
    let packed = bench(names::MATMUL_PACKED, Some(macs), budget, || {
        kernels::matmul_packed(black_box(&x), &panels, m)
    });
    pair(suite, names::RATIO_MATMUL_PACKED, dequant, packed);
    let saving = 1.0 - panels.resident_bytes() as f64 / panels.f32_equiv_bytes() as f64;
    println!(
        "  -> {} {saving:.3} ({} B packed vs {} B f32)",
        names::WEIGHT_MEM_SAVING_PACKED,
        panels.resident_bytes(),
        panels.f32_equiv_bytes()
    );
    suite.derive(names::WEIGHT_MEM_SAVING_PACKED, saving);

    // Transposed matmul (the tied LM head).
    let (tm, tk, tn) = (256usize, 512usize, 256usize);
    let xt = rng.normal_vec(tm * tk, 1.0);
    let wt = rng.normal_vec(tn * tk, 0.05);
    let tmacs = (tm * tk * tn) as u64;
    let scalar = bench(names::MATMUL_T_SCALAR, Some(tmacs), budget, || {
        kernels::matmul_transposed_scalar(black_box(&xt), &wt, tm, tk, tn)
    });
    let fast = bench(names::MATMUL_T_BLOCKED, Some(tmacs), budget, || {
        kernels::matmul_transposed(black_box(&xt), &wt, tm, tk, tn)
    });
    pair(suite, names::SPEEDUP_MATMUL_T, scalar, fast);

    // Quantizer: element-at-a-time scalar codec vs the branch-free slice
    // kernel, both writing the same output buffer (like-for-like bodies,
    // so the ratio measures the codec lanes, not a reduction chain).
    let xs = rng.normal_vec(1 << 16, 8.0);
    let mut qout = vec![0.0f32; xs.len()];
    let scalar = bench(names::QUANT_E4M3_SCALAR, Some(xs.len() as u64), budget, || {
        for (o, &v) in qout.iter_mut().zip(black_box(&xs)) {
            *o = quant_e4m3(v);
        }
    });
    let fast = bench(names::QUANT_E4M3_SLICE, Some(xs.len() as u64), budget, || {
        quant_e4m3_slice(black_box(&xs), &mut qout)
    });
    pair(suite, names::SPEEDUP_QUANT, scalar, fast);

    let r = bench(names::NVFP4_ROUNDTRIP, Some(xs.len() as u64), budget, || {
        nvfp4_roundtrip(black_box(&xs), &mut qout)
    });
    keep(suite, r);
}

/// Heavier pipeline benches: SW-Clip over a weight-sized tensor, the PPU +
/// blocked-multiply FGMP datapath, and a full native forward pass at the
/// `small-llama` preset architecture (random params — no artifacts
/// required, the arch comes straight from `SynthConfig::preset`).
pub fn pipeline_benches(suite: &mut BenchSuite, budget: Duration) {
    let mut rng = Rng::new(43);

    let cdata = rng.normal_vec(256 * 512, 0.05);
    let fisher: Vec<f32> = (0..cdata.len()).map(|_| rng.f32() + 1e-4).collect();
    let r = bench(names::SW_CLIP, Some(cdata.len() as u64), budget, || {
        sw_clip_tensor(black_box(&cdata), &fisher)
    });
    keep(suite, r);

    let (m, k, n) = (256usize, 512usize, 1536usize);
    let x = rng.normal_vec(m * k, 1.0);
    let w = rng.normal_vec(k * n, 0.05);
    let cw = vec![1.0f32; k];
    let scratch = MatmulScratch::new();
    let r = bench(names::FGMP_MATMUL, Some((m * k * n) as u64), budget, || {
        fgmp_matmul(black_box(&x), &w, m, k, n, &cw, 0.5, &scratch)
    });
    keep(suite, r);

    // The same datapath off the packed bits (PPU + in-register decode).
    let (panels, _) = quantized_panels(&w, k, n);
    let r = bench(names::FGMP_MATMUL_PACKED, Some((m * k * n) as u64), budget, || {
        fgmp_matmul_packed(black_box(&x), &panels, m, &cw, 0.5, &scratch)
    });
    keep(suite, r);

    // The d512 preset architecture — one definition, shared with synth.
    let (arch, params) = d512_model(&mut rng);
    let pm = Params::from_dense(
        params.iter().map(|(nm, v)| (nm.as_str(), v.as_slice())).collect(),
    );
    let (b, s) = (1usize, 32usize);
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % arch.vocab) as i32).collect();
    let r = bench(names::FORWARD_D512, Some((b * s) as u64), budget, || {
        forward(&arch, &pm, &tokens, b, s, None, None, false).unwrap()
    });
    keep(suite, r);
}

/// Shared d512 model setup for the decode workloads (random params at the
/// `small-llama` preset architecture — no artifacts required).
fn d512_model(rng: &mut Rng) -> (ModelArch, Vec<(String, Vec<f32>)>) {
    let arch = SynthConfig::preset("small-llama", 42).expect("small-llama preset").arch;
    let params: Vec<(String, Vec<f32>)> = arch
        .param_names()
        .iter()
        .map(|nm| {
            let len: usize = arch.param_shape(nm).iter().product();
            let data =
                if nm.contains("norm") { vec![1.0f32; len] } else { rng.normal_vec(len, 0.02) };
            (nm.clone(), data)
        })
        .collect();
    (arch, params)
}

/// Decode-throughput workloads at the d512 preset: the same 8-token
/// schedule decoded (a) KV-cached via `forward_prefill` + `forward_step`
/// and (b) by windowed full-sequence recompute (the pre-Engine serve
/// path), with their min-time ratio recorded as `speedup_decode_cached` —
/// the algorithmic win the stateful session API exists for. Plus one
/// batched `forward_step_batch` at occupancy 1/4/8 (the continuous-
/// batching shape).
pub fn decode_benches(suite: &mut BenchSuite, budget: Duration) {
    let mut rng = Rng::new(44);
    let (arch, params) = d512_model(&mut rng);
    let pm = Params::from_dense(
        params.iter().map(|(nm, v)| (nm.as_str(), v.as_slice())).collect(),
    );

    let prompt_len = 16usize;
    let gen = 8usize;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| ((i * 7) % arch.vocab) as i32).collect();
    let next: Vec<i32> = (0..gen).map(|i| ((i * 11 + 3) % arch.vocab) as i32).collect();

    // Prefill once; each cached iteration clones the warm cache and steps.
    let mut kv0 = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &prompt, None, &mut kv0).expect("prefill");

    let recompute = bench(names::DECODE_RECOMPUTE, Some(gen as u64), budget, || {
        let mut ctx = prompt.clone();
        for &t in &next {
            ctx.push(t);
            let s = ctx.len();
            black_box(forward(&arch, &pm, black_box(&ctx), 1, s, None, None, true).unwrap());
        }
    });
    let cached = bench(names::DECODE_CACHED, Some(gen as u64), budget, || {
        let mut kv = kv0.clone();
        for &t in &next {
            black_box(forward_step(&arch, &pm, black_box(t), &mut kv, None).unwrap());
        }
    });
    pair(suite, names::SPEEDUP_DECODE, recompute, cached);

    // Batched steps at fixed fill: step once, truncate the appended row —
    // the bench measures the decode step itself, not a warm-cache clone.
    let mut occ8_result: Option<crate::util::bench::BenchResult> = None;
    for (occ, name) in
        [(1usize, names::DECODE_OCC1), (4, names::DECODE_OCC4), (8, names::DECODE_OCC8)]
    {
        let toks: Vec<i32> = (0..occ).map(|i| ((i * 5 + 1) % arch.vocab) as i32).collect();
        let mut owned: Vec<KvState> = (0..occ).map(|_| kv0.clone()).collect();
        let r = bench(name, Some(occ as u64), budget, || {
            {
                let mut kvs: Vec<&mut KvState> = owned.iter_mut().collect();
                black_box(forward_step_batch(&arch, &pm, &toks, &mut kvs, None).unwrap());
            }
            for kv in &mut owned {
                kv.truncate(prompt_len);
            }
        });
        if occ == 8 {
            occ8_result = Some(r.clone());
        }
        keep(suite, r);
    }

    paged_benches(suite, budget, &arch, &pm, &prompt, occ8_result);
    suite.set_meta("decode.kv", "fp16 (flat + paged)");
}

/// Paged-arena decode/prefill workloads at the d512 preset: the occupancy-8
/// batched step over **paged** sessions (page-gather reads plus the
/// page-boundary alloc/free on the hot path; its min-time ratio against the
/// contiguous occupancy-8 step is `ratio_decode_paged_occ8_d512` — the
/// paged-decode floor CI gates), a high-session-churn variant cycling
/// admit → prefill_batch → step → retire over one shared pool, and
/// sequential-vs-batched prefill of 8 prompts with the derived
/// `speedup_prefill_batched_d512`.
fn paged_benches(
    suite: &mut BenchSuite,
    budget: Duration,
    arch: &ModelArch,
    pm: &Params<'_>,
    prompt: &[i32],
    occ8_contiguous: Option<crate::util::bench::BenchResult>,
) {
    use crate::model::kv::KvPool;

    let prompt_len = prompt.len();
    let occ = 8usize;
    let pages = 4 * KvPool::pages_for_session(arch.n_layers, arch.max_seq);
    let pool = KvPool::new(arch, KvPrecision::Fp16, pages);
    let toks: Vec<i32> = (0..occ).map(|i| ((i * 5 + 1) % arch.vocab) as i32).collect();

    // Paged occ-8 step at fixed fill (same body shape as the contiguous
    // occ benches: step + truncate, so the ratio isolates the paging).
    let mut owned: Vec<KvState> = (0..occ)
        .map(|_| {
            let mut kv = KvState::new_paged(arch, &pool);
            forward_prefill(arch, pm, prompt, None, &mut kv).expect("paged prefill");
            kv
        })
        .collect();
    let r = bench(names::DECODE_OCC8_PAGED, Some(occ as u64), budget, || {
        {
            let mut kvs: Vec<&mut KvState> = owned.iter_mut().collect();
            black_box(forward_step_batch(arch, pm, &toks, &mut kvs, None).unwrap());
        }
        for kv in &mut owned {
            kv.truncate(prompt_len);
        }
    });
    if let Some(base) = occ8_contiguous {
        let ratio = base.min.as_secs_f64() / r.min.as_secs_f64().max(1e-12);
        println!("  -> {} {ratio:.2}x", names::RATIO_DECODE_PAGED);
        suite.derive(names::RATIO_DECODE_PAGED, ratio);
    }
    keep(suite, r);
    drop(owned); // pages back to the free list before the churn bench

    // High session churn: every iteration admits 8 fresh sessions through
    // the batched prefill, steps them once, and retires them — the pool's
    // alloc/free cycling under continuous batching.
    let prompts: Vec<Vec<i32>> = (0..occ)
        .map(|i| (0..prompt_len).map(|t| ((t * 7 + i * 13 + 1) % arch.vocab) as i32).collect())
        .collect();
    let pviews: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let r = bench(
        names::DECODE_CHURN_PAGED,
        Some((occ * (prompt_len + 1)) as u64),
        budget,
        || {
            let mut kvs: Vec<KvState> = (0..occ).map(|_| KvState::new_paged(arch, &pool)).collect();
            {
                let mut refs: Vec<&mut KvState> = kvs.iter_mut().collect();
                black_box(forward_prefill_batch(arch, pm, &pviews, None, &mut refs).unwrap());
            }
            {
                let mut refs: Vec<&mut KvState> = kvs.iter_mut().collect();
                black_box(forward_step_batch(arch, pm, &toks, &mut refs, None).unwrap());
            }
            // kvs drop here: retirement returns every page.
        },
    );
    keep(suite, r);

    // Sequential vs batched prefill of the same 8 prompts (flat caches on
    // both sides, so the ratio isolates the matmul amortization).
    let seq = bench(names::PREFILL_SEQ, Some((occ * prompt_len) as u64), budget, || {
        for p in &prompts {
            let mut kv = KvState::new(arch, KvPrecision::Fp16);
            black_box(forward_prefill(arch, pm, p, None, &mut kv).unwrap());
        }
    });
    let bat = bench(names::PREFILL_BATCHED, Some((occ * prompt_len) as u64), budget, || {
        let mut kvs: Vec<KvState> =
            (0..occ).map(|_| KvState::new(arch, KvPrecision::Fp16)).collect();
        let mut refs: Vec<&mut KvState> = kvs.iter_mut().collect();
        black_box(forward_prefill_batch(arch, pm, &pviews, None, &mut refs).unwrap());
    });
    pair(suite, names::SPEEDUP_PREFILL_BATCHED, seq, bat);
}

/// Long-context decode at d512: one occupancy-1 decode step against a
/// ~4k-token KV window, FP16-stored vs FP8-stored cache. The FP8 step runs
/// the LUT-decode attention kernels straight off the stored E4M3 bytes (no
/// per-step f32 materialize), so its min-time ratio against the FP16 step
/// — `ratio_decode_longctx_fp8_d512` — is the CI floor guarding the
/// dequantize-free read path at the window sizes where attention reads
/// dominate the step. The window is filled by direct row appends (the
/// `small-llama` preset stops at max_seq 128, so this arch is built here).
pub fn longctx_benches(suite: &mut BenchSuite, budget: Duration) {
    let mut rng = Rng::new(45);
    let arch = ModelArch {
        vocab: 256,
        d_model: 512,
        n_layers: 2,
        n_heads: 8,
        d_ff: 1536,
        act: Act::SwiGlu,
        norm: NormKind::Rms,
        pos: PosKind::Rope,
        max_seq: 4096,
    };
    let params: Vec<(String, Vec<f32>)> = arch
        .param_names()
        .iter()
        .map(|nm| {
            let len: usize = arch.param_shape(nm).iter().product();
            let data =
                if nm.contains("norm") { vec![1.0f32; len] } else { rng.normal_vec(len, 0.02) };
            (nm.clone(), data)
        })
        .collect();
    let pm = Params::from_dense(
        params.iter().map(|(nm, v)| (nm.as_str(), v.as_slice())).collect(),
    );

    let window = 4094usize; // leaves room for the stepped row under max_seq
    let row = rng.normal_vec(arch.d_model, 0.05);
    let tok = [3i32];
    let mut fp16_min: Option<f64> = None;
    for (i, (prec, name)) in [
        (KvPrecision::Fp16, names::DECODE_LONGCTX_FP16),
        (KvPrecision::Fp8, names::DECODE_LONGCTX_FP8),
    ]
    .into_iter()
    .enumerate()
    {
        let mut kv = KvState::new(&arch, prec);
        for layer in &mut kv.layers {
            for _ in 0..window {
                layer.k.push_row(&row);
                layer.v.push_row(&row);
            }
        }
        kv.advance(window);
        let mut owned = [kv];
        let r = bench(name, Some(1), budget, || {
            {
                let mut kvs: Vec<&mut KvState> = owned.iter_mut().collect();
                black_box(forward_step_batch(&arch, &pm, &tok, &mut kvs, None).unwrap());
            }
            owned[0].truncate(window);
        });
        if i == 0 {
            fp16_min = Some(r.min.as_secs_f64());
        } else if let Some(base) = fp16_min {
            let ratio = base / r.min.as_secs_f64().max(1e-12);
            println!("  -> {} {ratio:.2}x", names::RATIO_DECODE_LONGCTX_FP8);
            suite.derive(names::RATIO_DECODE_LONGCTX_FP8, ratio);
        }
        keep(suite, r);
    }
    suite.set_meta("longctx.kv", "fp16+fp8 @ w4k");
}

/// Tensor-parallel decode scaling at the d512 preset: the same occupancy-8
/// decode step run (a) through the plain single-worker `forward_step_batch`
/// and (b) through the 2-worker sharded `forward_step_batch_tp` over
/// per-worker head-slice caches — exactly the split `ShardedEngine` serves
/// with. Their min-time ratio, `scaling_eff_decode_w2_d512`, is the CI
/// scaling floor: two workers must buy at least 1.15× single-worker decode
/// throughput (bit-identical logits, so this is pure wall-clock).
pub fn sharded_benches(suite: &mut BenchSuite, budget: Duration) {
    use crate::model::forward::{forward_prefill_batch_tp, forward_step_batch_tp};
    use crate::model::tp::{shard_arch, ShardPlan, ThreadCollective};

    let mut rng = Rng::new(46);
    let (arch, params) = d512_model(&mut rng);
    let pm = Params::from_dense(
        params.iter().map(|(nm, v)| (nm.as_str(), v.as_slice())).collect(),
    );

    let occ = 8usize;
    let prompt_len = 16usize;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| ((i * 7) % arch.vocab) as i32).collect();
    let toks: Vec<i32> = (0..occ).map(|i| ((i * 5 + 1) % arch.vocab) as i32).collect();

    // Single-worker reference: the plain batched step (what the one-worker
    // engine runs), same body shape as the occ benches (step + truncate).
    let mut kv0 = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &prompt, None, &mut kv0).expect("prefill");
    let mut owned: Vec<KvState> = (0..occ).map(|_| kv0.clone()).collect();
    let base = bench(names::DECODE_TP_W1, Some(occ as u64), budget, || {
        {
            let mut kvs: Vec<&mut KvState> = owned.iter_mut().collect();
            black_box(forward_step_batch(&arch, &pm, &toks, &mut kvs, None).unwrap());
        }
        for kv in &mut owned {
            kv.truncate(prompt_len);
        }
    });
    keep(suite, base.clone());

    // 2-worker sharded step over per-worker head-slice shard caches.
    let world = 2usize;
    let plan = ShardPlan::new(&arch, world).expect("shard plan");
    let arches: Vec<ModelArch> = plan
        .heads
        .iter()
        .filter(|(h0, h1)| h1 > h0)
        .map(|&(h0, h1)| shard_arch(&arch, h0, h1))
        .collect();
    let coll = ThreadCollective { world };
    let mut shards: Vec<Vec<KvState>> = (0..occ)
        .map(|_| arches.iter().map(|sa| KvState::new(sa, KvPrecision::Fp16)).collect())
        .collect();
    {
        let prefs: Vec<&[i32]> = (0..occ).map(|_| prompt.as_slice()).collect();
        let mut kvs: Vec<Vec<&mut KvState>> =
            shards.iter_mut().map(|s| s.iter_mut().collect()).collect();
        forward_prefill_batch_tp(&arch, &arches, &plan, &pm, &coll, &prefs, None, &mut kvs)
            .expect("tp prefill");
    }
    let r = bench(names::DECODE_TP_W2, Some(occ as u64), budget, || {
        {
            let mut kvs: Vec<Vec<&mut KvState>> =
                shards.iter_mut().map(|s| s.iter_mut().collect()).collect();
            black_box(
                forward_step_batch_tp(&arch, &arches, &plan, &pm, &coll, &toks, &mut kvs, None)
                    .unwrap(),
            );
        }
        for s in &mut shards {
            for kv in s.iter_mut() {
                kv.truncate(prompt_len);
            }
        }
    });
    let eff = base.min.as_secs_f64() / r.min.as_secs_f64().max(1e-12);
    println!("  -> {} {eff:.2}x", names::SCALING_EFF_DECODE_W2);
    suite.derive(names::SCALING_EFF_DECODE_W2, eff);
    keep(suite, r);
    suite.set_meta("sharded.workers", "1+2");
}

/// Fill one weight block with NVFP4-lattice values: every element is
/// `±m·2^e` with `m` on the E2M1 lattice and the block absmax pinned to
/// `6·2^e`, so the E4M3 encoding stores the values exactly AND the draft
/// view's NVFP4 re-encoding (block scale exactly `2^e`) is lossless — the
/// all-FP4 draft decodes bit-identically to the FP8 target, which pins the
/// speculative round at full accept (see `lattice_draft_view_is_lossless`).
fn fp4_lattice_block(rng: &mut Rng, out: &mut [f32]) {
    const M: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
    let e = rng.below(3) as i32 - 6; // 2^-6..2^-4: weight-sized magnitudes
    let s = (2.0f32).powi(e);
    for v in out.iter_mut() {
        let m = M[rng.below(8)];
        *v = if rng.below(2) == 0 { m * s } else { -m * s };
    }
    out[0] = 6.0 * s; // pin the absmax so the draft scale is exactly 2^e
}

/// Greedy next token off one logits row — `Session::next_token`'s
/// last-max-wins argmax.
fn argmax_row(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap()
}

/// Self-speculative decode at the d512 preset on a **draft-lossless**
/// fixture: every linear is quantized all-FP8 with block values pinned to
/// the NVFP4 lattice ([`fp4_lattice_block`]), so the all-NVFP4 draft view
/// decodes bit-identically to the target and every round accepts all k−1
/// guesses — the round is measured at its accept ceiling. One round =
/// session fork + (k−1) occupancy-1 draft steps off the NVFP4 view + one
/// k-row batched verify over the real cache + the rollback truncate —
/// exactly `SpecEngine::decode_step`'s datapath. The plain side decodes
/// the same k tokens with k occupancy-1 FGMP steps over the same packed
/// target; their per-token min-time ratio is `speedup_decode_spec_occ1_
/// d512` (CI floor 1.5). `draft_view_shrink_d512` prices the draft view
/// against the paper-mix (30% FP8) tensor it derives from (floor 1.15).
pub fn spec_benches(suite: &mut BenchSuite, budget: Duration) {
    use crate::model::forward::{forward_extend_batch, QuantInputs};
    use crate::model::kv::KvPool;

    let k = 4usize;
    let mut rng = Rng::new(47);
    let (arch, dense) = d512_model(&mut rng);
    let linears = arch.linears();

    // Lattice-pinned all-FP8 packed linears + their all-NVFP4 draft view.
    let packed: Vec<(String, PackedPanels)> = linears
        .iter()
        .map(|l| {
            let mut w = vec![0.0f32; l.n_out * l.k_in];
            for b in w.chunks_exact_mut(BLOCK) {
                fp4_lattice_block(&mut rng, b);
            }
            let prec = vec![Precision::Fp8; l.n_out * (l.k_in / BLOCK)];
            let t = FgmpTensor::pack(&[l.n_out, l.k_in], &w, &prec, None);
            (format!("{}.w", l.name), PackedPanels::from_tensor(&t, kernels::NR))
        })
        .collect();
    let drafts: Vec<(String, PackedPanels)> =
        packed.iter().map(|(n, p)| (n.clone(), p.to_all_fp4())).collect();

    let mut pm = Params::new();
    let mut pm_d = Params::new();
    for (n, v) in &dense {
        if !packed.iter().any(|(pn, _)| pn == n) {
            pm.insert_dense(n, v);
            pm_d.insert_dense(n, v);
        }
    }
    for (n, p) in &packed {
        pm.insert_packed(n, p);
    }
    for (n, p) in &drafts {
        pm_d.insert_packed(n, p);
    }
    let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
    let thr = vec![0.3f32; linears.len()];
    let q = QuantInputs { act_weights: awr, thresholds: &thr, attn_threshold: None };

    // One paged FP8-KV session at fixed fill — the serving decode shape.
    let prompt_len = 16usize;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| ((i * 7) % arch.vocab) as i32).collect();
    let pages = 8 * KvPool::pages_for_session(arch.n_layers, arch.max_seq);
    let pool = KvPool::new(&arch, KvPrecision::Fp8, pages);
    let mut kv = KvState::new_paged(&arch, &pool);
    forward_prefill(&arch, &pm, &prompt, Some(&q), &mut kv).expect("spec prefill");

    // Fixture sanity outside the timed region: off the lattice weights the
    // draft chain must equal the target chain token for token — that (not
    // hope) is what pins the measured round at full accept.
    let chain_of = |pmx: &Params<'_>, kv: &KvState| -> Vec<i32> {
        let mut f = kv.fork().expect("fork for chain check");
        let mut t = 1i32;
        let mut chain = vec![t];
        for _ in 1..k {
            let out = forward_step(&arch, pmx, t, &mut f, Some(&q)).unwrap();
            t = argmax_row(&out.logits);
            chain.push(t);
        }
        chain
    };
    assert_eq!(
        chain_of(&pm, &kv),
        chain_of(&pm_d, &kv),
        "lattice fixture must make the NVFP4 draft lossless"
    );

    // Plain side: the same k tokens, one greedy FGMP step at a time.
    let plain = bench(names::DECODE_SPEC_PLAIN, Some(k as u64), budget, || {
        let mut t = 1i32;
        for _ in 0..k {
            let out = forward_step(&arch, &pm, t, &mut kv, Some(&q)).unwrap();
            t = argmax_row(&out.logits);
        }
        black_box(t);
        kv.truncate(prompt_len);
    });

    // Speculative side: one full-accept round producing the same k tokens
    // (the chain head rides in free on the previous round's logits).
    let spec = bench(names::DECODE_SPEC_ROUND, Some(k as u64), budget, || {
        let mut draft = kv.fork().expect("draft fork");
        let mut chain = Vec::with_capacity(k);
        let mut t = 1i32;
        chain.push(t);
        for _ in 1..k {
            let out = forward_step(&arch, &pm_d, t, &mut draft, Some(&q)).unwrap();
            t = argmax_row(&out.logits);
            chain.push(t);
        }
        drop(draft); // draft pages return to the pool before the verify
        {
            let mut kvs: Vec<&mut KvState> = vec![&mut kv];
            let c: &[i32] = &chain;
            black_box(forward_extend_batch(&arch, &pm, &[c], &mut kvs, Some(&q)).unwrap());
        }
        kv.truncate(prompt_len); // rollback + fixed-fill reset in one
    });
    pair(suite, names::SPEEDUP_DECODE_SPEC, plain, spec);

    // Draft-view weight memory at the paper's 30% FP8 serving mix:
    // resident bytes of the mixed execution tensor over its all-NVFP4
    // draft view (computed arithmetically — same number serve reports).
    let w = rng.normal_vec(512 * 1536, 0.05);
    let (panels, _) = quantized_panels(&w, 512, 1536);
    let shrink = panels.resident_bytes() as f64 / panels.all_fp4_resident_bytes() as f64;
    println!(
        "  -> {} {shrink:.3} ({} B mixed vs {} B draft view)",
        names::DRAFT_VIEW_SHRINK,
        panels.resident_bytes(),
        panels.all_fp4_resident_bytes()
    );
    suite.derive(names::DRAFT_VIEW_SHRINK, shrink);

    suite.set_meta("spec.k", "4");
    suite.set_meta("spec.kv", "fp8-paged");
    suite.set_meta("spec.weights", "all-fp8 pinned to the nvfp4 lattice (lossless draft)");
}

/// Append `n` synthetic rows to every K/V buffer of a paged cache. The
/// page machinery never reads payloads — forward-level bit-exactness is
/// covered by the decode property tests — so the prefix workloads run on
/// fabricated rows and isolate the pool/trie costs from the matmuls.
fn append_rows(kv: &mut KvState, d_model: usize, n: usize, rng: &mut Rng) {
    kv.reserve(n).expect("pool sized for the workload");
    for _ in 0..n {
        let row = rng.normal_vec(d_model, 0.05);
        for l in &mut kv.layers {
            l.k.push_row(&row);
            l.v.push_row(&row);
        }
        kv.advance(1);
    }
}

/// Prefix-sharing workloads at the d512 preset: the O(page-table)
/// copy-on-write session fork against the pre-COW deep fork — their
/// min-time ratio is `speedup_fork_cow_d512` (CI floor 2.0) — plus the
/// coordinator's preempt/resume fast path (map a donated context back out
/// of the trie; `resume_reuse_frac_d512`, CI floor 0.8) and the
/// capacity demonstration the refcounted pool exists for: 64 live
/// sessions admitted through the prefix trie into a pool sized for 16
/// (4 shared 64-token system prompts, 8-token private suffixes — the
/// `shared_prefix_prompts` traffic `fgmp serve --shared-prefix` drives),
/// with the realized logical/unique sharing factor recorded as
/// `sharing_factor_prefix_d512` (CI floor 2.0).
pub fn prefix_benches(suite: &mut BenchSuite, budget: Duration) {
    use crate::io::synth::shared_prefix_prompts;
    use crate::model::kv::{KvPool, PAGE_TOKENS};
    use crate::runtime::prefix::PrefixIndex;

    let mut rng = Rng::new(48);
    let arch = SynthConfig::preset("small-llama", 42).expect("small-llama preset").arch;

    // -- fork cost: COW (page-table copy + refcount bumps) vs deep copy --
    let ctx = 7 * PAGE_TOKENS; // 112-token parent context under max_seq 128
    let pool = KvPool::new(
        &arch,
        KvPrecision::Fp16,
        4 * KvPool::pages_for_session(arch.n_layers, arch.max_seq),
    );
    let mut parent = KvState::new_paged(&arch, &pool);
    append_rows(&mut parent, arch.d_model, ctx, &mut rng);
    let copy = bench(names::SESSION_FORK_COPY, Some(1), budget, || {
        black_box(parent.fork_copy().expect("pool holds one full copy"));
    });
    let cow = bench(names::SESSION_FORK_COW, Some(1), budget, || {
        black_box(parent.fork().expect("COW fork allocates nothing"));
    });
    pair(suite, names::SPEEDUP_FORK_COW, copy, cow);
    drop(parent);

    // -- preempt/resume: the coordinator's page-level resume fast path --
    // On a pressure preemption the engine donates the victim's computed
    // pages to the trie before retiring it; the resume prompt (context +
    // the one produced-but-unconsumed token) then comes back as a
    // whole-page map plus a one-row suffix instead of a full re-prefill.
    let resume_ctx = 7 * PAGE_TOKENS + 1; // preempted context + produced token
    let resume: Vec<i32> = (0..resume_ctx).map(|i| ((i * 7 + 5) % arch.vocab) as i32).collect();
    let mut ix = PrefixIndex::new(pool.clone(), arch.n_layers);
    let mut donor = KvState::new_paged(&arch, &pool);
    append_rows(&mut donor, arch.d_model, resume_ctx - 1, &mut rng);
    ix.register(&resume[..resume_ctx - 1], &donor);
    drop(donor); // the preempted session retires; the trie holds its pages
    let hit_rows = ix.lookup(&resume).map_or(0, |h| h.rows);
    assert_eq!(hit_rows, resume_ctx - 1, "trie must hold the donated context");
    let r = bench(names::PREEMPT_RESUME, Some(1), budget, || {
        let mut kv = KvState::new_paged(&arch, &pool);
        if let Some(hit) = ix.lookup(&resume) {
            kv.map_prefix(&hit.per_buf_refs(), hit.rows, &hit.ppu);
        }
        append_rows(&mut kv, arch.d_model, resume.len() - hit_rows, &mut rng);
        black_box(&kv);
    });
    keep(suite, r);
    let frac = hit_rows as f64 / resume.len() as f64;
    println!(
        "  -> {} {frac:.3} ({hit_rows} of {} resume tokens from donated pages)",
        names::RESUME_REUSE_FRAC,
        resume.len()
    );
    suite.derive(names::RESUME_REUSE_FRAC, frac);
    drop(ix); // donated pages back to the free list before the capacity run

    // -- capacity: 64 sessions through the trie over a 16-session pool --
    let served = KvPool::new(
        &arch,
        KvPrecision::Fp8,
        16 * KvPool::pages_for_session(arch.n_layers, arch.max_seq),
    );
    let mut ix = PrefixIndex::new(served.clone(), arch.n_layers);
    let prompts = shared_prefix_prompts(48, 64, 4, 4 * PAGE_TOKENS, 8);
    let mut live: Vec<KvState> = Vec::with_capacity(prompts.len());
    for p in &prompts {
        let mut kv = KvState::new_paged(&arch, &served);
        let mapped = match ix.lookup(p) {
            Some(hit) => {
                kv.map_prefix(&hit.per_buf_refs(), hit.rows, &hit.ppu);
                hit.rows
            }
            None => 0,
        };
        append_rows(&mut kv, arch.d_model, p.len() - mapped, &mut rng);
        ix.register(p, &kv);
        live.push(kv);
    }
    let s = served.stats();
    let factor = s.sharing_factor();
    println!(
        "  -> {} {factor:.2}x ({} logical over {} unique pages; {} live sessions, \
         16-session pool)",
        names::SHARING_FACTOR_PREFIX,
        s.logical_pages,
        s.in_use_pages,
        live.len()
    );
    suite.derive(names::SHARING_FACTOR_PREFIX, factor);
    drop(live);
    suite.set_meta("prefix.workload", "64 sessions x (4 shared 64-tok prefixes + 8-tok suffix)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_gates_on_known_names() {
        // The checked-in CI baseline must only reference benches and
        // derived metrics this suite actually produces — otherwise the
        // perf gate fails every run with "in baseline but not in this
        // run". (Unit tests run with the package root as cwd.)
        let baseline = BenchSuite::load("ci/bench-baseline.json").expect("parse baseline");
        for r in &baseline.results {
            assert!(
                names::ALL.contains(&r.name.as_str()),
                "baseline bench '{}' is not produced by fgmp::benchsuite",
                r.name
            );
            assert!(r.elements.is_some(), "baseline '{}' lacks elements", r.name);
        }
        for key in baseline.derived.keys() {
            assert!(
                names::ALL_DERIVED.contains(&key.as_str()),
                "baseline derived '{key}' is not produced by fgmp::benchsuite"
            );
        }
        // The acceptance floors themselves: the blocked matmul, the
        // cached-decode-vs-recompute speedup, the batched-prefill speedup,
        // the paged-decode ratio, and the packed-execution floors
        // (throughput parity + resident weight-memory saving) must all be
        // gated.
        assert!(baseline.derived.get(names::SPEEDUP_MATMUL).is_some_and(|&v| v >= 2.0));
        assert!(baseline.derived.get(names::SPEEDUP_DECODE).is_some_and(|&v| v >= 1.0));
        assert!(baseline.derived.get(names::SPEEDUP_PREFILL_BATCHED).is_some_and(|&v| v >= 0.9));
        assert!(baseline.derived.get(names::RATIO_DECODE_PAGED).is_some_and(|&v| v >= 0.5));
        assert!(baseline.derived.get(names::RATIO_MATMUL_PACKED).is_some_and(|&v| v >= 0.9));
        assert!(baseline
            .derived
            .get(names::WEIGHT_MEM_SAVING_PACKED)
            .is_some_and(|&v| v >= 0.30));
        // The long-context stored-precision floor: FP8-KV attention through
        // the LUT-decode kernel must stay within ~1.4x of the f32 path.
        assert!(baseline
            .derived
            .get(names::RATIO_DECODE_LONGCTX_FP8)
            .is_some_and(|&v| v >= 0.7));
        // The tensor-parallel scaling floor: two workers must beat one on
        // the occupancy-8 decode step.
        assert!(baseline
            .derived
            .get(names::SCALING_EFF_DECODE_W2)
            .is_some_and(|&v| v >= 1.15));
        // The self-speculative decode floors: a full-accept k=4 round must
        // beat token-at-a-time decode by 1.5x, and the all-NVFP4 draft
        // view must be a real memory shrink over the paper-mix tensor.
        assert!(baseline.derived.get(names::SPEEDUP_DECODE_SPEC).is_some_and(|&v| v >= 1.5));
        assert!(baseline.derived.get(names::DRAFT_VIEW_SHRINK).is_some_and(|&v| v >= 1.15));
        // The prefix-sharing floors: a COW fork must beat the deep fork
        // by 2x, and trie admission must realize a ≥2x pool sharing
        // factor on the shared-prefix workload.
        assert!(baseline.derived.get(names::SPEEDUP_FORK_COW).is_some_and(|&v| v >= 2.0));
        assert!(baseline.derived.get(names::SHARING_FACTOR_PREFIX).is_some_and(|&v| v >= 2.0));
        // The preempt/resume floor: resuming a preempted request must come
        // mostly from donated trie pages, not a hidden full re-prefill.
        assert!(baseline.derived.get(names::RESUME_REUSE_FRAC).is_some_and(|&v| v >= 0.8));
    }

    #[test]
    fn groups_cover_exactly_the_canonical_names() {
        // The `--filter` registry and the canonical name lists must agree:
        // every bench and derived metric belongs to exactly one group, so
        // any baseline name a filter substring matches is guaranteed to be
        // produced by the groups that same substring selects.
        let mut benches: Vec<&str> = Vec::new();
        let mut derived: Vec<&str> = Vec::new();
        for (_, _, b, d) in GROUPS {
            benches.extend_from_slice(b);
            derived.extend_from_slice(d);
        }
        let mut all = names::ALL.to_vec();
        let mut all_derived = names::ALL_DERIVED.to_vec();
        benches.sort_unstable();
        derived.sort_unstable();
        all.sort_unstable();
        all_derived.sort_unstable();
        assert_eq!(benches, all, "GROUPS bench names out of sync with names::ALL");
        assert_eq!(derived, all_derived, "GROUPS derived names out of sync");
    }

    #[test]
    fn filter_selects_by_group_bench_and_derived_names() {
        let sel = |sub: &str| -> Vec<&str> {
            GROUPS
                .iter()
                .filter(|(g, _, b, d)| group_matches(Some(sub), g, b, d))
                .map(|(g, _, _, _)| *g)
                .collect()
        };
        assert_eq!(sel("spec"), vec!["spec"], "group name hit");
        assert_eq!(sel("longctx"), vec!["longctx"], "bench-name hit");
        assert_eq!(sel("shrink"), vec!["spec"], "derived-only names select their group");
        assert_eq!(sel("fork"), vec!["prefix"], "prefix benches select their group");
        assert_eq!(sel("sharing_factor"), vec!["prefix"], "derived sharing metric too");
        assert_eq!(sel("no_such_bench"), Vec::<&str>::new());
        // No filter runs everything.
        assert!(GROUPS.iter().all(|(g, _, b, d)| group_matches(None, g, b, d)));
    }

    #[test]
    fn lattice_draft_view_is_lossless() {
        // The property the spec bench fixture (and its 1.5x floor at full
        // accept) stands on: an all-FP8 tensor whose blocks sit on the
        // NVFP4 lattice with absmax 6·2^e re-quantizes to the all-NVFP4
        // draft view with zero error — the two packed forms decode to
        // bit-identical f32 weights.
        let (k, n) = (64usize, 48usize);
        let mut rng = Rng::new(9);
        let mut w = vec![0.0f32; n * k];
        for b in w.chunks_exact_mut(BLOCK) {
            fp4_lattice_block(&mut rng, b);
        }
        let prec = vec![Precision::Fp8; n * (k / BLOCK)];
        let t = FgmpTensor::pack(&[n, k], &w, &prec, None);
        let p = PackedPanels::from_tensor(&t, kernels::NR);
        let d = p.to_all_fp4();
        assert_eq!(p.unpack_kn(), d.unpack_kn(), "draft view must decode bit-identically");
        assert!(d.resident_bytes() < p.resident_bytes(), "draft view must shrink");
        assert_eq!(d.resident_bytes(), p.all_fp4_resident_bytes());
    }
}
