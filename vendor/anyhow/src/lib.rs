//! Minimal, dependency-free drop-in for the subset of the `anyhow` API this
//! repository uses: [`Error`], [`Result`], the [`Context`] trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Vendored as a path dependency so `cargo build` works with no registry
//! access (the build environment is fully offline). Error values carry a
//! message plus an optional boxed source; context layers prepend to the
//! message, so `Display`/`Debug` read like upstream anyhow's single-line and
//! "Caused by" formats respectively.

use std::fmt;

/// A string-backed error with an optional source, mirroring `anyhow::Error`
/// for the operations this codebase performs (construction, context
/// wrapping, display).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with an outer context message (inner message is preserved).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// View the underlying source as a concrete error type. The source is
    /// set whenever the error was built through the blanket `From`
    /// conversion (i.e. a typed `std::error::Error` bubbled up via `?`),
    /// and context layers preserve it — so typed conditions like
    /// backpressure errors survive `anyhow` plumbing, as upstream.
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(s) = &self.source {
            write!(f, "\n\nCaused by:\n    {s}")?;
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` and `Option` values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_layers_prepend() {
        let err: Result<()> = io_fail().context("reading config");
        let msg = err.unwrap_err().to_string();
        assert!(msg.starts_with("reading config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let err = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(err.to_string(), "missing thing");
    }

    #[test]
    fn downcast_ref_sees_through_context() {
        #[derive(Debug)]
        struct Marker(u32);
        impl fmt::Display for Marker {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let err: Error = Error::from(Marker(7));
        assert_eq!(err.downcast_ref::<Marker>().unwrap().0, 7);
        let wrapped = err.context("outer");
        assert_eq!(wrapped.downcast_ref::<Marker>().unwrap().0, 7);
        assert!(wrapped.downcast_ref::<std::fmt::Error>().is_none());
        assert!(Error::msg("plain").downcast_ref::<Marker>().is_none());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}, y = {y}", 1, y = 2);
        assert_eq!(e.to_string(), "x = 1, y = 2");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 7)
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "unreachable 7");
    }
}
