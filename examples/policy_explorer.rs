//! Policy explorer: visualize where the FP8 blocks land (paper Fig. 2b) and
//! how the three assignment policies disagree, layer by layer.
//!
//!     cargo run --release --example policy_explorer [artifacts] [model]

use fgmp::model::{ModelArtifacts, QuantConfig, QuantizedModel, RatioSpec};
use fgmp::policy::{Policy, ThresholdMode};
use fgmp::quant::Precision;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = std::env::args().nth(2).unwrap_or_else(|| "tiny-llama".into());
    let arts = ModelArtifacts::load(format!("{artifacts}/{model}"))?;

    // Fig. 2b: unstructured interleaving of FP8/FP4 blocks at 10% FP8.
    let cfg = QuantConfig::fgmp(0.9);
    let qm = QuantizedModel::quantize(&arts, &cfg)?;
    let target = qm
        .linears
        .iter()
        .find(|l| l.name.contains("fc1"))
        .expect("model has an fc1");
    println!("== precision map: {} (rows = output channels, cols = K blocks) ==", target.name);
    let bpr = target.assignment.blocks_per_row;
    for r in 0..24.min(target.packed.n_blocks / bpr) {
        let line: String = (0..bpr)
            .map(|b| match target.assignment.precision[r * bpr + b] {
                Precision::Fp8 => '#',
                Precision::Fp4 => '.',
            })
            .collect();
        println!("{line}");
    }

    // Per-layer FP8 fractions under each policy (the Fig. 6/7 raw material).
    println!("\n== per-linear weight FP8 fraction at 90% FP4 ==");
    println!("{:<18} {:>8} {:>8} {:>8}", "linear", "fisher", "qe", "oe");
    let mut per_policy = Vec::new();
    for pol in Policy::ALL {
        let cfg = QuantConfig {
            ratio: RatioSpec::Fp4Fraction(0.9),
            policy: pol,
            threshold_mode: if pol == Policy::Fisher {
                ThresholdMode::Global
            } else {
                ThresholdMode::Local // the paper's baselines use per-layer thresholds
            },
            sw_clip: false,
        };
        per_policy.push(QuantizedModel::quantize(&arts, &cfg)?);
    }
    for i in 0..arts.manifest.linears.len() {
        println!(
            "{:<18} {:>7.1}% {:>7.1}% {:>7.1}%",
            arts.manifest.linears[i].name,
            per_policy[0].linears[i].packed.fp8_fraction() * 100.0,
            per_policy[1].linears[i].packed.fp8_fraction() * 100.0,
            per_policy[2].linears[i].packed.fp8_fraction() * 100.0,
        );
    }
    println!("\nNote the Fisher column's spread across layers: the single global");
    println!("threshold allocates FP8 budget to sensitive layers (paper Fig. 7),");
    println!("while per-layer thresholds force every layer to the same 10%.");
    Ok(())
}
