//! END-TO-END DRIVER: the full system on a real small workload.
//!
//! Loads the trained tiny-llama checkpoint, FGMP-quantizes it at the
//! paper's headline operating point (70% FP4, Fisher policy, global
//! threshold, SW-Clip), starts the async serving coordinator (router →
//! dynamic batcher → PJRT executor), and drives it with a mixed stream of
//! scoring and generation requests from the held-out test corpus. Reports:
//!
//!   * perplexity vs the all-FP8 baseline (paper: <1% degradation)
//!   * simulated accelerator energy vs all-FP8 (paper: ~14% savings)
//!   * packed weight memory vs FP8 (paper: ~30% savings)
//!   * serving latency percentiles + throughput from the live coordinator
//!
//!     cargo run --release --example serve_batch [artifacts]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use fgmp::coordinator::{BatchPolicy, Request, RequestKind, Server, ServerConfig};
use fgmp::eval::Evaluator;
use fgmp::hwsim::memory::weight_memory_report;
use fgmp::model::{QuantConfig, QuantizedModel};
use fgmp::runtime::{ExecSpec, GraphKind, Runtime};

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;
    println!("platform {}  model tiny-llama  B={} S={}", rt.platform(), ev.batch, ev.seq);

    // --- offline: quantize at the headline point + the FP8 baseline ---
    let cfg = QuantConfig::fgmp(0.7);
    let t0 = std::time::Instant::now();
    let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
    println!("quantized {} linears in {:?} (weight FP8 {:.1}%)",
             qm.linears.len(), t0.elapsed(), qm.weight_fp8_fraction() * 100.0);
    let fp8_cfg = QuantConfig::all_fp8();
    let qm8 = QuantizedModel::quantize(&ev.arts, &fp8_cfg)?;

    let fp8_rep = ev.perplexity(&fp8_cfg, Some(&qm8), 8)?;
    let (base_mem, fgmp_mem, mem_savings) =
        weight_memory_report(ev.arts.manifest.quantized_elements(), qm.weight_fp8_fraction());

    // --- online: the serving coordinator ---
    let fwd_tail = ev.quant_arg_tail(&cfg, &qm)?;
    // logits graph has no mask arg; its tail is identical (params, aw, thr).
    let fwd_spec = ExecSpec::new(&artifacts, "tiny-llama", GraphKind::FwdQuant);
    let logits_spec = ExecSpec::new(&artifacts, "tiny-llama", GraphKind::LogitsQuant);
    let logits_tail = fwd_tail.clone();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &fp8_rep.act_fp8);

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy::default(),
        layer_shapes: shapes,
        queue_depth: 512,
        kv_precision: fgmp::model::KvPrecision::Fp8,
        decode_batch: 4,
        kv_pages: None,
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
    };
    let windows = ev.eval_windows(16);
    let seq = ev.seq;

    let server = Server::start(scfg, fwd_spec, fwd_tail, logits_spec, logits_tail)?;
    let t0 = std::time::Instant::now();

    // scoring stream: every test window as its own request
    let mut rxs = Vec::new();
    let mut id = 0u64;
    for w in &windows {
        for row in w.chunks_exact(seq) {
            let (req, rx) = Request::new(
                id,
                RequestKind::Score { tokens: row.to_vec(), mask: vec![1.0; seq] },
            );
            id += 1;
            server.router.submit(req)?;
            rxs.push(rx);
        }
    }
    // a few generation requests interleaved
    let mut gen_rxs = Vec::new();
    for g in 0..4 {
        let prompt = windows[g][..32].to_vec();
        let (req, rx) = Request::new(
            100_000 + g as u64,
            RequestKind::Generate { prompt, n_tokens: 8 },
        );
        server.router.submit(req)?;
        gen_rxs.push(rx);
    }

    let mut nll = 0.0;
    let mut toks = 0.0;
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            if let Some((s, n)) = r.nll {
                nll += s;
                toks += n;
            }
        }
    }
    for rx in gen_rxs {
        if let Ok(r) = rx.recv() {
            if let Some(g) = r.generated {
                println!("generated {:?}... in {:?}", &g[..g.len().min(8)], r.latency);
            }
        }
    }
    let wall = t0.elapsed();
    let ppl = (nll / toks).exp();
    let snap = server.metrics.snapshot();

    println!("\n================= END-TO-END REPORT =================");
    println!("served         : {} score rows + {} generated tokens in {:.2}s",
             snap.requests, snap.generated_tokens, wall.as_secs_f64());
    println!("throughput     : {:.0} scored tokens/s", toks / wall.as_secs_f64());
    println!("latency        : p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms (batch fill {:.0}%)",
             snap.p50_ms, snap.p95_ms, snap.p99_ms, snap.mean_batch_fill * 100.0);
    println!("decode         : {:.1} tok/s  ttft p50 {:.1} ms  occupancy {:.2}",
             snap.decode_tok_per_s, snap.ttft_p50_ms, snap.mean_decode_occupancy);
    println!("perplexity     : {:.4} vs FP8 {:.4}  ({:+.2}%  | paper: <1%)",
             ppl, fp8_rep.ppl, (ppl / fp8_rep.ppl - 1.0) * 100.0);
    println!("sim energy     : {:.3} mJ vs FP8 {:.3} mJ  (savings {:.1}%  | paper: 14%)",
             snap.energy_j * 1e3, snap.energy_fp8_j * 1e3, snap.energy_savings * 100.0);
    println!("weight memory  : {:.3} MiB vs FP8 {:.3} MiB (savings {:.1}%  | paper: 30%)",
             fgmp_mem.total_mib(), base_mem.total_mib(), mem_savings * 100.0);
    println!("=====================================================");
    server.shutdown();
    Ok(())
}
