//! Quickstart: load a trained model, quantize it with FGMP, check the
//! perplexity cost and the efficiency wins, in ~40 lines of API.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have run first.

use fgmp::eval::Evaluator;
use fgmp::hwsim::memory::weight_memory_report;
use fgmp::model::{QuantConfig, QuantizedModel};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // Load the AOT-compiled graphs + calibration artifacts for tiny-llama.
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;

    // The paper's headline configuration: 70% of blocks in NVFP4, selected
    // by the Fisher-weighted impact score with a single global threshold,
    // SW-Clip on the FP4 weight blocks.
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;

    // Compare against the all-FP8 baseline (the paper's reference point).
    let fp8_cfg = QuantConfig::all_fp8();
    let qm8 = QuantizedModel::quantize(&ev.arts, &fp8_cfg)?;

    let fgmp = ev.perplexity(&cfg, Some(&qm), 8)?;
    let fp8 = ev.perplexity(&fp8_cfg, Some(&qm8), 8)?;

    let (base_mem, fgmp_mem, savings) =
        weight_memory_report(ev.arts.manifest.quantized_elements(), qm.weight_fp8_fraction());

    println!("\n== FGMP 70% FP4 vs all-FP8 ==");
    println!("perplexity     : {:.4} vs {:.4}  ({:+.2}%)", fgmp.ppl, fp8.ppl,
             (fgmp.ppl / fp8.ppl - 1.0) * 100.0);
    println!("weight blocks  : {:.1}% FP8", qm.weight_fp8_fraction() * 100.0);
    println!("act blocks     : {:.1}% FP8 (measured online by the PPU)",
             fgmp.mean_act_fp8() * 100.0);
    println!("weight memory  : {:.3} MiB vs {:.3} MiB  (saves {:.1}%)",
             fgmp_mem.total_mib(), base_mem.total_mib(), savings * 100.0);
    Ok(())
}
