//! Energy-model exploration: how the datapath geometry, PPU sharing, and
//! precision mixes interact — the design-space view behind Figs. 9/10 and
//! the Table 4 amortization argument.
//!
//!     cargo run --release --example energy_sweep

use fgmp::hwsim::datapath::{simulate_matmul, DatapathConfig, MatmulJob};
use fgmp::hwsim::energy::EnergyModel;
use fgmp::hwsim::kmeans::{kmeans, LayerConfig};
use fgmp::hwsim::ppu::ppu_balance;

fn main() {
    let em = EnergyModel::default();

    // 1. Energy per op across the precision-mix diagonal.
    println!("== dot-product energy/op (pJ) along the W=A diagonal ==");
    let cfg = DatapathConfig::default();
    for i in 0..=10 {
        let p = i as f64 / 10.0;
        let job = MatmulJob { m: 1024, k: 1024, n: 1024, weight_fp8: p, act_fp8: p };
        let r = simulate_matmul(&cfg, &em, &job, true);
        let bar = "#".repeat((r.energy_per_op() * 200.0) as usize);
        println!("{:>4.0}% FP8  {:>7.4}  {}", p * 100.0, r.energy_per_op(), bar);
    }

    // 2. PE scaling vs PPU balance.
    println!("\n== PPU balance across matmul shapes (one PPU, 16 lanes) ==");
    println!("{:<28} {:>10} {:>14}", "shape", "max PEs", "note");
    for (m, k, n) in [(4096, 4096, 4096), (512, 4096, 4096), (4096, 512, 4096), (128, 1024, 1024)] {
        let b = ppu_balance(&DatapathConfig::default(), m, k, n, 1);
        let note = if b.max_pes_per_ppu >= 256 { "amortizes fully" } else { "PPU-bound sooner" };
        println!("{:<28} {:>10} {:>14}", format!("{m}x{k}x{n}"), b.max_pes_per_ppu, note);
    }

    // 3. The §4.3 clustering pipeline on a synthetic layer population.
    println!("\n== K-means layer-config clustering (paper §4.3) ==");
    let pts: Vec<LayerConfig> = (0..512)
        .map(|i| LayerConfig {
            weight_fp8: ((i * 37) % 100) as f64 / 150.0,
            act_fp8: ((i * 61) % 100) as f64 / 200.0,
        })
        .collect();
    for k in [4, 16, 100] {
        let c = kmeans(&pts, k, 100);
        let err: f64 = pts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let cen = &c.centroids[c.assignment[i]];
                ((p.weight_fp8 - cen.weight_fp8).powi(2) + (p.act_fp8 - cen.act_fp8).powi(2)).sqrt()
            })
            .sum::<f64>()
            / pts.len() as f64;
        println!("K={k:<4} mean centroid distance {err:.4}");
    }
    println!("\n(paper uses K=100: effectively exact while replacing 512 power");
    println!("simulations with 100 representative kernels)");
}
