"""Build-time training of the tiny model families on tiny-corpus.

AdamW + cosine decay; deliberately short runs (a few hundred steps on CPU)
whose only job is to produce transformers with *trained* weight/activation
statistics — heavy-tailed, outlier-carrying — so the FGMP sensitivity policy
has the structure the paper exploits. Checkpoints land in artifacts/<model>/
via the FGTN container.

Usage: python -m compile.train --model tiny-llama --steps 400 --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import tensorio


def adamw_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.int32(0)}


@functools.partial(jax.jit, static_argnames=("cfg", "lr", "wd", "warmup", "total"))
def train_step(cfg, params, opt, tokens, lr=3e-3, wd=0.01, warmup=40, total=400):
    loss, grads = jax.value_and_grad(lambda p: model_mod.mean_loss(cfg, p, tokens))(params)
    t = opt["t"] + 1
    tf = t.astype(jnp.float32)
    sched = jnp.minimum(tf / warmup, 0.5 * (1 + jnp.cos(math.pi * jnp.minimum(tf / total, 1.0))))
    step_lr = lr * sched
    # global-norm clip at 1.0
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
    b1, b2, eps = 0.9, 0.95, 1e-8
    new_p, new_m, new_v = {}, {}, {}
    for k, g in grads.items():
        g = g * scale
        m = b1 * opt["m"][k] + (1 - b1) * g
        v = b2 * opt["v"][k] + (1 - b2) * g * g
        mhat = m / (1 - b1**tf)
        vhat = v / (1 - b2**tf)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        decay = wd if k.endswith(".w") or "embed" in k else 0.0
        new_p[k] = params[k] - step_lr * (upd + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_nll(cfg, params, tokens):
    mask = jnp.ones(tokens.shape, jnp.float32)
    s, n, _ = model_mod.nll(cfg, params, tokens, mask)
    return s.sum(), n.sum()


def evaluate(cfg, params, stream, batch=8, seq=128, max_batches=16):
    tot_s, tot_n = 0.0, 0.0
    for i, win in enumerate(data_mod.eval_windows(stream, batch, seq)):
        if i >= max_batches:
            break
        s, n = eval_nll(cfg, params, jnp.asarray(win))
        tot_s += float(s)
        tot_n += float(n)
    return math.exp(tot_s / tot_n)


def train_model(name: str, out_dir: str, steps: int = 400, batch: int = 32, seq: int = 64,
                seed: int = 0, log_every: int = 50) -> dict:
    cfg = model_mod.FAMILIES[name]
    corpus = data_mod.TinyCorpus()
    train_stream, valid_stream, _ = corpus.splits()
    params = model_mod.init_params(cfg, seed=seed)
    opt = adamw_init(params)
    gen = data_mod.batches(train_stream, batch, seq, seed=seed + 100)
    t0 = time.time()
    losses = []
    for step in range(steps):
        tokens = jnp.asarray(next(gen))
        params, opt, loss = train_step(cfg, params, opt, tokens, total=steps)
        losses.append(float(loss))
        if (step + 1) % log_every == 0 or step == 0:
            print(f"[{name}] step {step + 1}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    ppl = evaluate(cfg, params, valid_stream)
    n_params = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"[{name}] done: valid ppl {ppl:.3f}, {n_params / 1e6:.2f}M params, "
          f"{time.time() - t0:.1f}s", flush=True)

    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)
    tensorio.save(os.path.join(mdir, "weights.fgtn"),
                  {k: np.asarray(v) for k, v in params.items()})
    meta = {
        "name": name,
        "config": {k: getattr(cfg, k) for k in
                   ("vocab", "d_model", "n_layers", "n_heads", "d_ff", "act", "norm", "pos", "max_seq")},
        "steps": steps,
        "valid_ppl": ppl,
        "n_params": n_params,
        "loss_curve": losses[:: max(1, len(losses) // 100)],
        "train_seconds": time.time() - t0,
    }
    with open(os.path.join(mdir, "train_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = list(model_mod.FAMILIES) if args.model == "all" else [args.model]
    # Persist the corpus splits once for the Rust evaluator.
    corpus = data_mod.TinyCorpus()
    train_s, valid_s, test_s = corpus.splits()
    os.makedirs(args.out, exist_ok=True)
    tensorio.save(os.path.join(args.out, "corpus.fgtn"),
                  {"train": train_s[:262144], "valid": valid_s, "test": test_s})
    for n in names:
        train_model(n, args.out, steps=args.steps)


if __name__ == "__main__":
    main()
