"""L2: the transformer model families and their FGMP-quantized forward.

Three tiny families stand in for the paper's Llama-2 / GPT3 / Nemotron-4
model sets (DESIGN.md SS2): same block structure as the originals, trained at
build time on tiny-corpus. The *quantized* forward threads every linear layer
(QKV / O_proj / FC1 / FC2, exactly the four the paper profiles in Fig. 7)
through the L1 `fgmp_matmul` Pallas kernel; per-linear activation sensitivity
vectors and thresholds are graph *inputs*, so one exported HLO serves every
mixed-precision ratio, every assignment policy, and the all-FP8/all-FP4
baselines. Weights enter the graph already round-tripped (the Rust side owns
weight-side FGMP + SW-Clip), and norms/embeddings/attention internals stay in
high precision, matching the paper's scope (linear layers only).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.fgmp_matmul import fgmp_matmul

LINEAR_KINDS = ("qkv_proj", "o_proj", "fc1", "fc2")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture descriptor for one model family member."""

    name: str
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 704
    act: str = "swiglu"  # swiglu | gelu | relu2
    norm: str = "rms"  # rms | ln
    pos: str = "rope"  # rope | learned
    max_seq: int = 512

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def fc1_out(self) -> int:
        # SwiGLU fuses gate+up into one FC1 matmul (2*d_ff outputs).
        return 2 * self.d_ff if self.act == "swiglu" else self.d_ff

    def linears(self):
        """The linear-layer inventory: (name, layer, kind, k_in, n_out)."""
        out = []
        for l in range(self.n_layers):
            out.append((f"blk{l}.qkv_proj", l, "qkv_proj", self.d_model, 3 * self.d_model))
            out.append((f"blk{l}.o_proj", l, "o_proj", self.d_model, self.d_model))
            out.append((f"blk{l}.fc1", l, "fc1", self.d_model, self.fc1_out()))
            out.append((f"blk{l}.fc2", l, "fc2", self.d_ff, self.d_model))
        return out

    def param_names(self):
        """Ordered parameter list — this order is the HLO argument order."""
        names = ["embed"]
        if self.pos == "learned":
            names.append("pos_embed")
        for l in range(self.n_layers):
            names += [
                f"blk{l}.norm1",
                f"blk{l}.qkv_proj.w",
                f"blk{l}.o_proj.w",
                f"blk{l}.norm2",
                f"blk{l}.fc1.w",
                f"blk{l}.fc2.w",
            ]
            if self.norm == "ln":
                names += [f"blk{l}.norm1.b", f"blk{l}.norm2.b"]
        names.append("final_norm")
        if self.norm == "ln":
            names.append("final_norm.b")
        return names

    def param_shape(self, name: str):
        d, dff = self.d_model, self.d_ff
        if name == "embed":
            return (self.vocab, d)
        if name == "pos_embed":
            return (self.max_seq, d)
        if name.endswith("qkv_proj.w"):
            return (d, 3 * d)
        if name.endswith("o_proj.w"):
            return (d, d)
        if name.endswith("fc1.w"):
            return (d, self.fc1_out())
        if name.endswith("fc2.w"):
            return (dff, d)
        return (d,)  # norms and biases


# The published model roster -> our build-time stand-ins (DESIGN.md SS2).
FAMILIES: dict[str, ModelConfig] = {
    "tiny-llama": ModelConfig(
        name="tiny-llama", d_model=256, n_layers=4, n_heads=4, d_ff=704,
        act="swiglu", norm="rms", pos="rope",
    ),
    "tiny-llama-l": ModelConfig(
        name="tiny-llama-l", d_model=320, n_layers=6, n_heads=5, d_ff=880,
        act="swiglu", norm="rms", pos="rope",
    ),
    "tiny-gpt": ModelConfig(
        name="tiny-gpt", d_model=192, n_layers=4, n_heads=4, d_ff=768,
        act="gelu", norm="ln", pos="learned",
    ),
    "tiny-gpt-l": ModelConfig(
        name="tiny-gpt-l", d_model=288, n_layers=5, n_heads=6, d_ff=1152,
        act="gelu", norm="ln", pos="learned",
    ),
    "tiny-nemotron": ModelConfig(
        name="tiny-nemotron", d_model=224, n_layers=6, n_heads=4, d_ff=896,
        act="relu2", norm="rms", pos="rope",
    ),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Scaled-normal init (GPT-2 style residual scaling)."""
    rng = np.random.RandomState(seed)
    params: dict[str, jnp.ndarray] = {}
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        if name.endswith(".b"):
            arr = np.zeros(shape, np.float32)
        elif name.endswith("norm1") or name.endswith("norm2") or name == "final_norm":
            arr = np.ones(shape, np.float32)
        elif name.endswith(".w"):
            std = 0.02 * (resid_scale if ("o_proj" in name or "fc2" in name) else 1.0)
            arr = rng.randn(*shape).astype(np.float32) * std * math.sqrt(256 / shape[0])
        else:  # embeddings
            arr = rng.randn(*shape).astype(np.float32) * 0.02
        params[name] = jnp.asarray(arr)
    return params


def _norm(cfg: ModelConfig, params, prefix: str, x):
    g = params[prefix]
    if cfg.norm == "rms":
        return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-5) * g
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + params[prefix + ".b"]


def _rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over (B, H, S, Dh)."""
    b, h, s, dh = x.shape
    half = dh // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    t = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(t), jnp.sin(t)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _mlp_act(cfg: ModelConfig, f1: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        gate, up = jnp.split(f1, 2, axis=-1)
        return jax.nn.silu(gate) * up
    if cfg.act == "gelu":
        return jax.nn.gelu(f1)
    return jnp.square(jax.nn.relu(f1))  # Nemotron-style squared ReLU


def _attention(cfg: ModelConfig, qkv: jnp.ndarray) -> jnp.ndarray:
    """Causal MHA from fused qkv (B, S, 3D) -> (B, S, D). High precision."""
    b, s, _ = qkv.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    if cfg.pos == "rope":
        q, k = _rope(q), _rope(k)
    att = jnp.einsum("bhsd,bhtd->bhst", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


class LinearFn:
    """How the forward applies a linear layer.

    PLAIN      : f32 matmul (training / BF16 reference graph).
    FGMP_PALLAS: the L1 fused kernel (exported quantized graph).
    FGMP_REF   : pure-jnp oracle (calibration + python-side tests; has a
                 well-defined VJP, unlike interpret-mode pallas_call reverse).
    """

    PLAIN, FGMP_PALLAS, FGMP_REF = "plain", "fgmp_pallas", "fgmp_ref"


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,
    *,
    linear_fn: str = LinearFn.PLAIN,
    act_weights: list | None = None,
    thresholds: jnp.ndarray | None = None,
    act_taps: list | None = None,
    return_inputs: bool = False,
):
    """Transformer forward -> (logits, per-linear FP8 block fractions).

    act_weights : per-linear (K,) channel-sensitivity vectors (quant modes).
    thresholds  : (num_linears,) impact-score thresholds (quant modes).
    act_taps    : optional list of zero tensors added to each linear input;
                  grads w.r.t. them give the activation Fisher (calibrate.py).
    return_inputs: also return the (rows, K) input of every linear layer
                  (calibration statistics; adds a third output).
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    if cfg.pos == "learned":
        x = x + params["pos_embed"][:s][None, :, :]

    li = 0
    fracs = []
    captured = []

    def linear(h2d, wname):
        nonlocal li
        w = params[wname + ".w"]
        if act_taps is not None:
            h2d = h2d + act_taps[li]
        if return_inputs:
            captured.append(h2d)
        if linear_fn == LinearFn.PLAIN:
            y, frac = h2d @ w, jnp.float32(0.0)
        elif linear_fn == LinearFn.FGMP_PALLAS:
            m, n = h2d.shape[0], w.shape[1]
            k = w.shape[0]
            # Full-width N tile: quantization runs once per M tile (no
            # replication). M tiles as large as the VMEM budget allows
            # (~4 MiB of f32 per grid step) — fewer interpret-mode grid
            # iterations, ~20% faster per kernel (EXPERIMENTS.md §Perf L2).
            budget = 4 * 1024 * 1024 // 4  # f32 elements
            tile_m = m
            while tile_m > 128 and (tile_m * (k + n) > budget or m % tile_m != 0):
                tile_m //= 2
            if m % tile_m != 0:
                tile_m = m
            y, frac = fgmp_matmul(h2d, w, act_weights[li], thresholds[li],
                                  tile_m=tile_m, tile_n=n)
        else:
            y, frac = ref.fgmp_matmul_ref(h2d, w, act_weights[li], thresholds[li])
        li += 1
        fracs.append(frac)
        return y

    for l in range(cfg.n_layers):
        h = _norm(cfg, params, f"blk{l}.norm1", x)
        qkv = linear(h.reshape(b * s, -1), f"blk{l}.qkv_proj").reshape(b, s, -1)
        attn = _attention(cfg, qkv)
        o = linear(attn.reshape(b * s, -1), f"blk{l}.o_proj").reshape(b, s, -1)
        x = x + o
        h = _norm(cfg, params, f"blk{l}.norm2", x)
        f1 = linear(h.reshape(b * s, -1), f"blk{l}.fc1").reshape(b, s, -1)
        act = _mlp_act(cfg, f1)
        f2 = linear(act.reshape(b * s, -1), f"blk{l}.fc2").reshape(b, s, -1)
        x = x + f2

    x = _norm(cfg, params, "final_norm", x)
    logits = x @ params["embed"].T  # tied LM head (high precision, as in paper)
    fr = jnp.stack(fracs) if fracs else jnp.zeros((0,))
    if return_inputs:
        return logits, fr, captured
    return logits, fr


def nll(cfg, params, tokens, mask, **kw):
    """Per-sequence masked next-token NLL.

    tokens (B, S) i32; mask (B, S) f32 — position t is scored iff mask[t]=1,
    predicting tokens[t] from tokens[<t]. Returns (nll_sum (B,), ntok (B,),
    fp8 fractions (num_linears,)).
    """
    logits, fracs = forward(cfg, params, tokens, **kw)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    token_lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return -(token_lp * m).sum(axis=-1), m.sum(axis=-1), fracs


def mean_loss(cfg, params, tokens, **kw):
    """Scalar mean NLL over all next-token positions (training objective)."""
    mask = jnp.ones(tokens.shape, jnp.float32)
    s, n, _ = nll(cfg, params, tokens, mask, **kw)
    return s.sum() / n.sum()
