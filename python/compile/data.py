"""Tiny-corpus: the synthetic stand-in for Wikitext-103 (DESIGN.md SS2).

A first-order Markov language over a 512-token vocabulary with Zipfian
unigram statistics and sparse per-state successor sets, segmented into
sentences by a BOS token. This gives a next-token-prediction task with a
non-trivial entropy floor, so perplexity *degradation* under quantization —
the paper's accuracy metric — is meaningfully measurable. Everything is
deterministic in `seed`.
"""

from __future__ import annotations

import numpy as np

VOCAB = 512
BOS = 0
SUCCESSORS = 24  # sparse out-degree per state
SENT_LEN_MEAN = 24


class TinyCorpus:
    """Deterministic synthetic corpus with train/valid/test splits."""

    def __init__(self, seed: int = 1234, vocab: int = VOCAB):
        self.vocab = vocab
        rng = np.random.RandomState(seed)
        # Zipfian target unigram distribution over non-BOS tokens.
        ranks = np.arange(1, vocab, dtype=np.float64)
        zipf = 1.0 / ranks**1.05
        self.unigram = zipf / zipf.sum()
        # Each state gets a sparse successor set biased toward frequent
        # tokens, with Dirichlet transition probabilities. This makes some
        # channels / contexts far more predictable than others — the
        # heterogeneity the FGMP sensitivity policy feeds on.
        self.succ = np.zeros((vocab, SUCCESSORS), dtype=np.int64)
        self.succ_p = np.zeros((vocab, SUCCESSORS), dtype=np.float64)
        for s in range(vocab):
            cand = rng.choice(vocab - 1, size=SUCCESSORS, replace=False, p=self.unigram) + 1
            self.succ[s] = cand
            alpha = rng.uniform(0.05, 0.6)
            p = rng.dirichlet(np.full(SUCCESSORS, alpha))
            self.succ_p[s] = p
        self._cum = np.cumsum(self.succ_p, axis=1)

    def sample(self, n_tokens: int, seed: int) -> np.ndarray:
        """Sample a token stream of length n_tokens (BOS-delimited sentences)."""
        rng = np.random.RandomState(seed)
        out = np.empty(n_tokens, dtype=np.int32)
        state = BOS
        remaining = 0
        # Draw all uniforms up front; the loop is plain indexing.
        us = rng.random_sample(n_tokens)
        lens = rng.poisson(SENT_LEN_MEAN, size=n_tokens // 8 + 2).clip(4)
        li = 0
        for i in range(n_tokens):
            if remaining == 0:
                out[i] = BOS
                state = BOS
                remaining = int(lens[li])
                li += 1
                continue
            j = int(np.searchsorted(self._cum[state], us[i]))
            j = min(j, SUCCESSORS - 1)
            state = int(self.succ[state, j])
            out[i] = state
            remaining -= 1
        return out

    def splits(self, train: int = 1_000_000, valid: int = 65_536, test: int = 65_536):
        """The canonical train/valid/test streams (seeds disjoint by design)."""
        return (
            self.sample(train, seed=1),
            self.sample(valid, seed=2),
            self.sample(test, seed=3),
        )

    def continuation_logprob_rank(self) -> None:  # pragma: no cover
        raise NotImplementedError


def batches(stream: np.ndarray, batch: int, seq: int, seed: int = 0, loop: bool = True):
    """Yield (batch, seq) i32 windows sampled uniformly from a token stream."""
    rng = np.random.RandomState(seed)
    n = len(stream) - seq - 1
    while True:
        idx = rng.randint(0, n, size=batch)
        yield np.stack([stream[i : i + seq] for i in idx]).astype(np.int32)
        if not loop:
            break


def eval_windows(stream: np.ndarray, batch: int, seq: int):
    """Deterministic non-overlapping eval windows covering the stream."""
    n = (len(stream) - 1) // seq
    wins = [stream[i * seq : i * seq + seq] for i in range(n)]
    for i in range(0, len(wins) - batch + 1, batch):
        yield np.stack(wins[i : i + batch]).astype(np.int32)


def make_cloze_suite(
    corpus: TinyCorpus,
    stream: np.ndarray,
    *,
    n_items: int,
    ctx_len: int,
    cont_len: int,
    hard: bool,
    seed: int,
):
    """Build a 4-way multiple-choice cloze suite (stand-in for MMLU /
    lm-eval-harness tasks; DESIGN.md SS2).

    Each item: a context window from the held-out stream, the true
    continuation, and 3 distractors. `hard` distractors are *corruptions*
    of the true continuation (each token replaced with a uniformly random
    token with probability ~0.5) — same length and largely overlapping, but
    the corrupted transitions are off-manifold, so a model that has learned
    the transition structure prefers the truth. (Same-state Markov
    re-samples would be statistically indistinguishable from the truth by
    construction and score at chance.) Easy distractors are Markov samples
    from a random unrelated state. Scored like lm-eval: argmax of mean
    per-token logprob over the continuation.
    """
    rng = np.random.RandomState(seed)
    items = []
    n = len(stream) - ctx_len - cont_len - 1
    for _ in range(n_items):
        i = rng.randint(0, n)
        ctx = stream[i : i + ctx_len].astype(np.int32)
        true_cont = stream[i + ctx_len : i + ctx_len + cont_len].astype(np.int32)
        opts = [true_cont]
        for _ in range(3):
            if hard:
                cont = true_cont.copy()
                # Corrupt ~2 tokens: enough off-manifold signal to beat
                # chance, few enough that quantization noise can flip the
                # ranking (keeps the suite discriminative across precisions).
                flips = rng.random_sample(cont_len) < (2.0 / cont_len)
                if not flips.any():
                    flips[rng.randint(cont_len)] = True
                cont[flips] = rng.randint(1, corpus.vocab, size=int(flips.sum()))
            else:
                s = int(rng.randint(1, corpus.vocab))
                cont = np.empty(cont_len, dtype=np.int32)
                for t in range(cont_len):
                    u = rng.random_sample()
                    j = min(int(np.searchsorted(corpus._cum[s], u)), SUCCESSORS - 1)
                    s = int(corpus.succ[s, j])
                    cont[t] = s
            opts.append(cont)
        order = rng.permutation(4)
        items.append(
            {
                "context": ctx.tolist(),
                "options": [opts[o].tolist() for o in order],
                "answer": int(np.where(order == 0)[0][0]),
            }
        )
    return items
