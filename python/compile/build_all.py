"""One-shot artifact build: corpus -> train -> calibrate -> AOT -> tasks.

This is what `make artifacts` runs (a no-op when artifacts/ is up to date;
the Makefile handles staleness). Python never runs again after this — the
Rust binary is self-contained against artifacts/.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from . import aot, calibrate, tasks, train
from . import model as model_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--models", default="all")
    args = ap.parse_args()
    names = list(model_mod.FAMILIES) if args.models == "all" else args.models.split(",")
    t0 = time.time()

    os.makedirs(args.out, exist_ok=True)
    # Corpus + model training.
    import sys

    sys.argv = ["train", "--model", "all" if args.models == "all" else names[0],
                "--steps", str(args.steps), "--out", args.out]
    if args.models == "all":
        from . import data as data_mod
        from . import tensorio
        corpus = data_mod.TinyCorpus()
        tr, va, te = corpus.splits()
        tensorio.save(os.path.join(args.out, "corpus.fgtn"),
                      {"train": tr[:262144], "valid": va, "test": te})
        for nm in names:
            train.train_model(nm, args.out, steps=args.steps)
    else:
        train.main()

    for nm in names:
        calibrate.calibrate_model(nm, args.out)
    for nm in names:
        aot.export_model(nm, args.out)
    sys.argv = ["tasks", "--out", args.out]
    tasks.main()

    with open(os.path.join(args.out, "BUILD_STAMP.json"), "w") as f:
        json.dump({"models": names, "steps": args.steps,
                   "seconds": time.time() - t0}, f)
    print(f"artifact build complete in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
