"""Pure-jnp reference oracles for the FGMP quantization numerics.

This module is the *specification* of the number formats used throughout the
reproduction. The Pallas kernels (nvfp4.py / fp8.py / fgmp_matmul.py) and the
bit-exact Rust codecs (rust/src/quant/) must agree with these functions to
the last ULP; pytest/hypothesis and the checked-in golden vectors enforce it.

Formats
-------
* FP8 E4M3 (OCP "FN" variant): bias 7, 3 mantissa bits, max normal 448,
  min normal 2^-6, min subnormal 2^-9. No infinities; we saturate to +-448.
* FP4 E2M1: bias 1, 1 mantissa bit, grid {0, 0.5, 1, 1.5, 2, 3, 4, 6} with
  sign. Saturates to +-6.
* NVFP4: 16-element blocks of E2M1 values with one E4M3 scale per block
  (scale = round_e4m3(absmax / 6) by default, or an explicit clipped scale).

All rounding is round-to-nearest, ties-to-even on the quantized mantissa
(implemented as `round(x / quantum)` with jnp.round, which is ties-to-even),
matching `f32::round_ties_even` on the Rust side.
"""

from __future__ import annotations

import jax.numpy as jnp

# Format constants (shared with the Rust side; see rust/src/quant/fp{4,8}.rs).
E4M3_MAX = 448.0
E4M3_MIN_NORMAL = 2.0**-6
E4M3_QUANTUM_SUBNORMAL = 2.0**-9  # spacing below the min normal
E2M1_MAX = 6.0
E2M1_MIN_NORMAL = 1.0
E2M1_QUANTUM_SUBNORMAL = 0.5
BLOCK = 16  # NVFP4 / FGMP block size (= VMAC vector length, paper SS4)


def _floor_log2(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(|x|)) for positive finite x, via the f32 exponent field."""
    bits = jnp.abs(x).astype(jnp.float32).view(jnp.int32)
    return (bits >> 23) - 127


def quant_e4m3(x: jnp.ndarray) -> jnp.ndarray:
    """Round-trip f32 -> E4M3 -> f32 (saturating, RNE)."""
    x = x.astype(jnp.float32)
    ax = jnp.abs(x)
    e = _floor_log2(jnp.where(ax > 0, ax, 1.0))
    # 3 mantissa bits: spacing within binade 2^e is 2^(e-3); subnormals flat.
    quantum = jnp.where(
        ax < E4M3_MIN_NORMAL,
        E4M3_QUANTUM_SUBNORMAL,
        jnp.exp2((e - 3).astype(jnp.float32)),
    )
    q = jnp.round(x / quantum) * quantum
    # Re-rounding can bump into the next binade (e.g. 0.9999 -> 1.0): that is
    # exactly representable, so no correction needed. Saturate the top.
    return jnp.clip(q, -E4M3_MAX, E4M3_MAX)


def quant_e2m1(x: jnp.ndarray) -> jnp.ndarray:
    """Round-trip f32 -> E2M1 -> f32 (saturating, RNE). Input is pre-scaled."""
    x = x.astype(jnp.float32)
    ax = jnp.abs(x)
    e = _floor_log2(jnp.where(ax > 0, ax, 1.0))
    # 1 mantissa bit: spacing within binade 2^e is 2^(e-1); subnormals 0.5.
    quantum = jnp.where(
        ax < E2M1_MIN_NORMAL,
        E2M1_QUANTUM_SUBNORMAL,
        jnp.exp2((e - 1).astype(jnp.float32)),
    )
    q = jnp.round(x / quantum) * quantum
    return jnp.clip(q, -E2M1_MAX, E2M1_MAX)


def nvfp4_scale(block_absmax: jnp.ndarray) -> jnp.ndarray:
    """Dynamic-max per-block scale: round_e4m3(absmax/6). A zero block gets
    scale 0, which the caller substitutes with 1 to avoid 0/0."""
    return quant_e4m3(block_absmax / E2M1_MAX)


def quant_nvfp4(x: jnp.ndarray, scale: jnp.ndarray | None = None):
    """Round-trip a tensor through NVFP4 along its last axis.

    x        : (..., K) with K % 16 == 0.
    scale    : optional explicit per-block scales (..., K//16); when None the
               dynamic-max scale is used (the paper's online activation path).
    returns  : (dequantized tensor, per-block scales actually used).
    """
    orig = x.shape
    xb = x.reshape(*orig[:-1], orig[-1] // BLOCK, BLOCK).astype(jnp.float32)
    if scale is None:
        scale = nvfp4_scale(jnp.max(jnp.abs(xb), axis=-1))
    safe = jnp.where(scale > 0, scale, 1.0)
    q = quant_e2m1(xb / safe[..., None]) * safe[..., None]
    q = jnp.where(scale[..., None] > 0, q, 0.0)
    return q.reshape(orig), scale


def quant_fp8_block(x: jnp.ndarray) -> jnp.ndarray:
    """Round-trip through plain (unscaled) E4M3 — the paper's high format."""
    return quant_e4m3(x)


def block_impact(x: jnp.ndarray, chan_weight: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 8: per-block sensitivity-weighted increase in quantization
    error when stored in NVFP4 instead of FP8.

    x           : (..., K) values.
    chan_weight : (K,) per-input-channel weighting (Fisher g^2 for the FGMP
                  policy; ones for the Quantization-Error baseline; mean |Q|^2
                  of the other tensor for the Output-Error baseline).
    returns     : (..., K//16) impact scores.
    """
    q4, _ = quant_nvfp4(x)
    q8 = quant_fp8_block(x)
    d = (q4 - q8) * jnp.sqrt(chan_weight.astype(jnp.float32))
    db = d.reshape(*x.shape[:-1], x.shape[-1] // BLOCK, BLOCK)
    return jnp.sum(db * db, axis=-1)


def fgmp_quant(x: jnp.ndarray, chan_weight: jnp.ndarray, threshold):
    """Reference FGMP activation quantizer (the PPU, paper SS4.2).

    Blocks whose impact score exceeds `threshold` are kept in FP8; the rest
    are quantized to NVFP4. Returns (mixed round-trip tensor, fp8 block mask).
    """
    q4, _ = quant_nvfp4(x)
    q8 = quant_fp8_block(x)
    score = block_impact(x, chan_weight)
    keep_fp8 = score > threshold
    mask = jnp.repeat(keep_fp8, BLOCK, axis=-1).reshape(x.shape)
    return jnp.where(mask, q8, q4), keep_fp8


def fgmp_matmul_ref(x, w_q, chan_weight, threshold):
    """Reference for the fused FGMP kernel: quantize activations to mixed
    precision on the fly, then matmul against pre-quantized weights.

    x: (M, K) f32, w_q: (K, N) already round-tripped weights.
    Returns (y (M, N), fp8_fraction scalar).
    """
    xq, keep = fgmp_quant(x, chan_weight, threshold)
    y = xq @ w_q
    frac = jnp.mean(keep.astype(jnp.float32))
    return y, frac
