"""L1 Pallas kernels for FGMP quantization (build-time only).

`ref` is the pure-jnp numerics specification; `nvfp4`/`fp8`/`fgmp_matmul`
are the Pallas implementations (interpret=True) that lower into the exported
HLO. The Rust codecs in rust/src/quant/ mirror `ref` bit-for-bit.
"""

from . import fgmp_matmul, fp8, nvfp4, ref  # noqa: F401
