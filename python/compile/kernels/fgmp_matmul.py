"""Pallas kernel: the FGMP hot-spot — fused on-the-fly mixed-precision
activation quantization (the paper's PPU, SS4.2) + matmul against
pre-quantized weights (the paper's FGMP VMAC datapath, SS4.1).

TPU mapping (DESIGN.md SS3):
  * grid over (M tiles, N tiles); each step holds an (TILE_M, K) activation
    tile and a (K, TILE_N) weight tile in VMEM — the scratchpad analogue of
    the paper's weight-stationary PE collectors.
  * the paper's four parallel dot-product units become branch-free masked
    arithmetic: both the FP4-grid and FP8-grid round-trips of each activation
    block are computed vectorized and selected by the per-block impact-score
    mask — the SIMD analogue of clock-gating three of four units.
  * the per-block impact score sum_i g_i^2 (Q4(x_i)-Q8(x_i))^2 > T compare is
    the PPU; it runs while the tile is resident in VMEM, i.e. "before writing
    out to memory" exactly as in the paper.
  * the matmul itself is f32 here (interpret mode); on a real TPU it is the
    bf16 MXU op while the quantizer is overlappable VPU work.

Outputs both the matmul result and the per-tile count of FP8 blocks so the
L2 graph can report per-layer precision mixes to the Rust energy model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .nvfp4 import e4m3_roundtrip, nvfp4_roundtrip_tile

BLOCK = ref.BLOCK


def fgmp_quant_tile(x, chan_weight, threshold):
    """FGMP-quantize a (..., K) tile: returns (mixed tensor, fp8-block mask).

    chan_weight is the per-input-channel sensitivity (K,) — Fisher g^2 for
    the paper's policy; the weighting array is an argument so the same kernel
    also runs the Quantization-Error / Output-Error baseline policies.
    """
    shape = x.shape
    q4 = nvfp4_roundtrip_tile(x)
    q8 = e4m3_roundtrip(x)
    d = (q4 - q8) * jnp.sqrt(chan_weight)
    db = d.reshape(*shape[:-1], shape[-1] // BLOCK, BLOCK)
    score = jnp.sum(db * db, axis=-1)
    keep_fp8 = score > threshold
    mask = jnp.repeat(keep_fp8, BLOCK, axis=-1).reshape(shape)
    return jnp.where(mask, q8, q4), keep_fp8


def _fgmp_matmul_kernel(x_ref, w_ref, cw_ref, t_ref, y_ref, nfp8_ref):
    xq, keep = fgmp_quant_tile(x_ref[...], cw_ref[...], t_ref[0])
    y_ref[...] = xq @ w_ref[...]
    # Count of FP8 blocks in this activation tile. Each activation tile is
    # quantized once per N-tile in this schedule; the host divides by the
    # N-grid size (grid dims are static, so this is exact).
    nfp8_ref[0, 0] = jnp.sum(keep.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n"))
def fgmp_matmul(
    x: jnp.ndarray,
    w_q: jnp.ndarray,
    chan_weight: jnp.ndarray,
    threshold: jnp.ndarray,
    tile_m: int = 128,
    tile_n: int = 128,
):
    """Fused FGMP activation-quant + matmul.

    x           : (M, K) f32 activations (high precision, pre-PPU).
    w_q         : (K, N) f32 weights already round-tripped through FGMP.
    chan_weight : (K,) per-channel sensitivity for the impact score.
    threshold   : scalar f32; blocks scoring above stay FP8 (+inf => all FP4,
                  -inf/negative => all FP8).
    returns     : (y (M, N) f32, fp8_fraction scalar f32).
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and k % BLOCK == 0
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    assert m % tile_m == 0 and n % tile_n == 0
    gm, gn = m // tile_m, n // tile_n
    thr = jnp.reshape(threshold.astype(jnp.float32), (1,))
    y, nfp8 = pl.pallas_call(
        _fgmp_matmul_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ),
        interpret=True,
    )(x.astype(jnp.float32), w_q.astype(jnp.float32), chan_weight.astype(jnp.float32), thr)
    total_blocks = m * (k // BLOCK)
    # Every M-tile recomputes the same quantization for each of its gn
    # N-tiles; average the counts over one N column to undo the replication.
    frac = jnp.sum(nfp8[:, 0]) / total_blocks
    return y, frac
