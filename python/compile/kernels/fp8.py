"""Pallas kernel: plain E4M3 quantize-dequantize (the FGMP high format)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nvfp4 import e4m3_roundtrip


def _fp8_kernel(x_ref, o_ref):
    o_ref[...] = e4m3_roundtrip(x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_m",))
def fp8_quant(x: jnp.ndarray, tile_m: int = 128) -> jnp.ndarray:
    """E4M3 round-trip of a (M, K) tensor, tiled (tile_m, K)."""
    m, k = x.shape
    tile_m = min(tile_m, m)
    assert m % tile_m == 0, f"M={m} must be a multiple of tile_m={tile_m}"
    return pl.pallas_call(
        _fp8_kernel,
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        grid=(m // tile_m,),
        in_specs=[pl.BlockSpec((tile_m, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        interpret=True,
    )(x.astype(jnp.float32))
