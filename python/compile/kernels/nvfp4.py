"""Pallas kernel: NVFP4 (E2M1 + per-16-block E4M3 scale) quantize-dequantize.

TPU adaptation of the paper's block quantizer (DESIGN.md SS3): each grid step
holds one (TILE_M, K) activation tile in VMEM; the E2M1/E4M3 round-trips are
branch-free element-wise VPU work (exponent extraction via bitcast, quantum
multiply, ties-to-even round) — the vectorized analogue of the per-lane
quantizer hardware.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO (see /opt/xla-example/README).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = ref.BLOCK


def _floor_log2(ax: jnp.ndarray) -> jnp.ndarray:
    bits = ax.astype(jnp.float32).view(jnp.int32)
    return (bits >> 23) - 127


def e4m3_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    """In-kernel E4M3 round-trip; identical math to ref.quant_e4m3."""
    ax = jnp.abs(x)
    e = _floor_log2(jnp.where(ax > 0, ax, 1.0))
    quantum = jnp.where(
        ax < ref.E4M3_MIN_NORMAL,
        ref.E4M3_QUANTUM_SUBNORMAL,
        jnp.exp2((e - 3).astype(jnp.float32)),
    )
    q = jnp.round(x / quantum) * quantum
    return jnp.clip(q, -ref.E4M3_MAX, ref.E4M3_MAX)


def e2m1_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    """In-kernel E2M1 round-trip; identical math to ref.quant_e2m1."""
    ax = jnp.abs(x)
    e = _floor_log2(jnp.where(ax > 0, ax, 1.0))
    quantum = jnp.where(
        ax < ref.E2M1_MIN_NORMAL,
        ref.E2M1_QUANTUM_SUBNORMAL,
        jnp.exp2((e - 1).astype(jnp.float32)),
    )
    q = jnp.round(x / quantum) * quantum
    return jnp.clip(q, -ref.E2M1_MAX, ref.E2M1_MAX)


def nvfp4_roundtrip_tile(x: jnp.ndarray) -> jnp.ndarray:
    """NVFP4 round-trip of a (..., K) tile with dynamic-max block scales."""
    shape = x.shape
    xb = x.reshape(*shape[:-1], shape[-1] // BLOCK, BLOCK)
    scale = e4m3_roundtrip(jnp.max(jnp.abs(xb), axis=-1) / ref.E2M1_MAX)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = e2m1_roundtrip(xb / safe[..., None]) * safe[..., None]
    q = jnp.where(scale[..., None] > 0, q, 0.0)
    return q.reshape(shape)


def _nvfp4_kernel(x_ref, o_ref):
    o_ref[...] = nvfp4_roundtrip_tile(x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_m",))
def nvfp4_quant(x: jnp.ndarray, tile_m: int = 128) -> jnp.ndarray:
    """NVFP4 quantize-dequantize of a (M, K) tensor along K, as a Pallas
    kernel tiled (tile_m, K) so each grid step fits in VMEM."""
    m, k = x.shape
    assert k % BLOCK == 0, f"K={k} must be a multiple of {BLOCK}"
    tile_m = min(tile_m, m)
    assert m % tile_m == 0, f"M={m} must be a multiple of tile_m={tile_m}"
    return pl.pallas_call(
        _nvfp4_kernel,
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        grid=(m // tile_m,),
        in_specs=[pl.BlockSpec((tile_m, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        interpret=True,
    )(x.astype(jnp.float32))
