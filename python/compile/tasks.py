"""Generate the synthetic downstream-task suites (stand-ins for MMLU and the
lm-eval-harness selection; DESIGN.md SS2, paper Tables 2-3).

Six 4-way multiple-choice suites over held-out tiny-corpus text, scored like
lm-eval (argmax mean per-token logprob over the continuation):

  mmlu-tiny   : hard distractors (same-state Markov continuations), long ctx
  race-tiny   : long context, medium continuations
  hellaswag-tiny, piqa-tiny, winogrande-tiny, boolq-tiny : varying
                context/continuation lengths and distractor difficulty.

Usage: python -m compile.tasks --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

from . import data as data_mod

SUITES = {
    # name: (n_items, ctx_len, cont_len, hard, seed)
    "mmlu-tiny": (256, 48, 8, True, 11),
    "race-tiny": (192, 64, 12, True, 12),
    "hellaswag-tiny": (192, 32, 10, True, 13),
    "piqa-tiny": (192, 24, 8, False, 14),
    "winogrande-tiny": (192, 40, 6, True, 15),
    "boolq-tiny": (192, 56, 8, False, 16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    corpus = data_mod.TinyCorpus()
    _, _, test_stream = corpus.splits()
    tdir = os.path.join(args.out, "tasks")
    os.makedirs(tdir, exist_ok=True)
    for name, (n, ctx, cont, hard, seed) in SUITES.items():
        items = data_mod.make_cloze_suite(
            corpus, test_stream, n_items=n, ctx_len=ctx, cont_len=cont,
            hard=hard, seed=seed,
        )
        with open(os.path.join(tdir, f"{name}.json"), "w") as f:
            json.dump({"name": name, "ctx_len": ctx, "cont_len": cont,
                       "items": items}, f)
        print(f"wrote {name}: {n} items", flush=True)


if __name__ == "__main__":
    main()
