"""Emit golden quantization vectors for the Rust codec tests.

The Rust codecs (rust/src/quant/) must match ref.py bit-for-bit; this writes
a deterministic JSON fixture of inputs and expected round-trips that
rust/tests/quant_golden.rs replays. Regenerate with:

    python -m compile.golden --out ../rust/tests/golden/quant_golden.json
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from .kernels import ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/tests/golden/quant_golden.json")
    args = ap.parse_args()
    rs = np.random.RandomState(42)

    # Mixed-magnitude scalars, including boundary/tie cases for both formats.
    special = np.array(
        [0.0, -0.0, 0.25, -0.25, 0.5, 0.75, 1.25, 1.75, 3.5, 5.0, 6.0, 7.0,
         448.0, 456.0, 500.0, -448.0, 2.0**-9, 2.0**-9 * 0.5, 2.0**-10,
         2.0**-6, 2.0**-6 * 0.99, 1.0 / 3.0, np.pi, -np.e, 100.0, 447.9],
        dtype=np.float32,
    )
    rand = np.concatenate([
        rs.randn(200).astype(np.float32) * 3,
        rs.randn(100).astype(np.float32) * 100,
        (rs.randn(100) * 0.01).astype(np.float32),
    ])
    scalars = np.concatenate([special, rand])

    e4m3 = np.asarray(ref.quant_e4m3(scalars))
    e2m1 = np.asarray(ref.quant_e2m1(scalars))

    # NVFP4 blocks (dynamic-max scaling) and impact scores.
    blocks = (rs.randn(32, ref.BLOCK) * np.exp(rs.randn(32, 1))).astype(np.float32)
    nv, scales = ref.quant_nvfp4(blocks.reshape(1, -1))
    nv = np.asarray(nv).reshape(32, ref.BLOCK)
    scales = np.asarray(scales).ravel()
    cw = np.abs(rs.randn(32 * ref.BLOCK)).astype(np.float32)
    impact = np.asarray(ref.block_impact(blocks.reshape(1, -1), cw)).ravel()

    out = {
        "scalars": scalars.tolist(),
        "e4m3": e4m3.tolist(),
        "e2m1": e2m1.tolist(),
        "blocks": blocks.tolist(),
        "nvfp4_roundtrip": nv.tolist(),
        "nvfp4_scales": scales.tolist(),
        "impact_chan_weight": cw.tolist(),
        "impact_scores": impact.tolist(),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
