"""AOT export: lower the L2 graphs to HLO *text* for the Rust runtime.

Interchange is HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Per model we export three graphs (shapes frozen at export; recorded in
manifest.json together with the exact argument order):

  fwd_quant  (tokens i32[B,S], mask f32[B,S], *params, *act_weights,
              thresholds f32[NL]) -> (nll_sum f32[B], ntok f32[B],
              fp8_frac f32[NL])
      The FGMP-quantized forward through the L1 Pallas kernels. Weights are
      fed already round-tripped by the Rust quantizer; thresholds are inputs
      so a single compiled executable serves every ratio R, every policy
      weighting, and the all-FP8 (-1) / all-FP4 (+1e30) baselines.

  fwd_ref    (tokens, mask, *params) -> (nll_sum, ntok)
      Unquantized reference (the BF16 row of the paper's tables).

  logits_quant (tokens, *params, *act_weights, thresholds) -> f32[B, V]
      Last-position logits for the serving/generation path.

Usage: python -m compile.aot --model all --out ../artifacts [--batch 8 --seq 128]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(cfg: model_mod.ModelConfig, batch: int, seq: int):
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, seq), jnp.float32)
    params = [jax.ShapeDtypeStruct(cfg.param_shape(n), jnp.float32) for n in cfg.param_names()]
    aw = [jax.ShapeDtypeStruct((k,), jnp.float32) for (_, _, _, k, _) in cfg.linears()]
    thr = jax.ShapeDtypeStruct((len(cfg.linears()),), jnp.float32)
    return tok, mask, params, aw, thr


def export_model(name: str, out_dir: str, batch: int = 8, seq: int = 128) -> None:
    cfg = model_mod.FAMILIES[name]
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)
    pnames = cfg.param_names()
    linears = cfg.linears()
    tok, mask, pspecs, awspecs, thrspec = _specs(cfg, batch, seq)

    def fwd_quant(tokens, mask, *rest):
        params = dict(zip(pnames, rest[: len(pnames)]))
        aws = list(rest[len(pnames) : len(pnames) + len(linears)])
        thr = rest[-1]
        s, n, fr = model_mod.nll(
            cfg, params, tokens, mask,
            linear_fn=model_mod.LinearFn.FGMP_PALLAS,
            act_weights=aws, thresholds=thr,
        )
        return s, n, fr

    def fwd_ref(tokens, mask, *rest):
        params = dict(zip(pnames, rest))
        s, n, _ = model_mod.nll(cfg, params, tokens, mask)
        return s, n

    def logits_quant(tokens, *rest):
        params = dict(zip(pnames, rest[: len(pnames)]))
        aws = list(rest[len(pnames) : len(pnames) + len(linears)])
        thr = rest[-1]
        logits, _ = model_mod.forward(
            cfg, params, tokens,
            linear_fn=model_mod.LinearFn.FGMP_PALLAS,
            act_weights=aws, thresholds=thr,
        )
        return (logits[:, -1, :],)

    exports = {
        "fwd_quant": (fwd_quant, (tok, mask, *pspecs, *awspecs, thrspec)),
        "fwd_ref": (fwd_ref, (tok, mask, *pspecs)),
        "logits_quant": (logits_quant, (tok, *pspecs, *awspecs, thrspec)),
    }
    for gname, (fn, specs) in exports.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(mdir, f"{gname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[{name}] wrote {gname}: {len(text) / 1e6:.2f} MB", flush=True)

    manifest = {
        "name": name,
        "batch": batch,
        "seq": seq,
        "vocab": cfg.vocab,
        "num_linears": len(linears),
        "param_names": pnames,
        "param_shapes": {n: list(cfg.param_shape(n)) for n in pnames},
        "linears": [
            {"name": nm, "layer": l, "kind": kind, "k_in": k, "n_out": n}
            for (nm, l, kind, k, n) in linears
        ],
        "graphs": {
            "fwd_quant": {
                "args": ["tokens", "mask", *pnames,
                         *[f"act_weight:{nm}" for (nm, *_ ) in linears], "thresholds"],
                "outputs": ["nll_sum[B]", "ntok[B]", "fp8_frac[NL]"],
            },
            "fwd_ref": {
                "args": ["tokens", "mask", *pnames],
                "outputs": ["nll_sum[B]", "ntok[B]"],
            },
            "logits_quant": {
                "args": ["tokens", *pnames,
                         *[f"act_weight:{nm}" for (nm, *_ ) in linears], "thresholds"],
                "outputs": ["last_logits[B,V]"],
            },
        },
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    names = list(model_mod.FAMILIES) if args.model == "all" else [args.model]
    for nm in names:
        export_model(nm, args.out, batch=args.batch, seq=args.seq)


if __name__ == "__main__":
    main()
