"""Offline Fisher-information calibration (paper SS3.1-3.2, build-time).

Mirrors the paper's procedure on our substrate: average squared gradients of
the loss over a calibration sample from the training stream, giving

  * per-element weight Fisher  E[g^2]        -> fisher_w.fgtn
  * per-input-channel activation Fisher      -> act_fisher.fgtn
  * per-input-channel mean |X|^2 (OE policy) -> act_msq.fgtn
  * activation impact-score quantile tables  -> act_score_quantiles.fgtn
    (per policy in {fisher, qe, oe}: a global 99-point quantile curve and a
    per-linear table; these are the threshold <-> ratio-R lookup the Rust
    coordinator uses to set the PPU threshold, eq. 9-10)

The paper used 512 samples x 512 seq on an A100 (<3 min); we use the same
batch-count scale on CPU with the tiny models and record wall-clock in
EXPERIMENTS.md.

Usage: python -m compile.calibrate --model tiny-llama --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import tensorio
from .kernels import ref

QUANTS = np.arange(1, 100, dtype=np.float64) / 100.0  # q = 0.01 .. 0.99
POLICIES = ("fisher", "qe", "oe")


def _loss_with_taps(cfg, params, taps, tokens):
    return model_mod.mean_loss(cfg, params, tokens, act_taps=taps)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _grad_step(cfg, params, tokens):
    """One calibration batch: grads w.r.t. weights and linear inputs."""
    b, s = tokens.shape
    taps = [jnp.zeros((b * s, k), jnp.float32) for (_, _, _, k, _) in cfg.linears()]
    gw, gt = jax.grad(_loss_with_taps, argnums=(1, 2))(cfg, params, taps, tokens)
    fisher_w = {k + ".fisher": g * g for k, g in gw.items() if k.endswith(".w")}
    act_fisher = [jnp.mean(g * g, axis=0) for g in gt]
    return fisher_w, act_fisher


@functools.partial(jax.jit, static_argnames=("cfg",))
def _capture_step(cfg, params, tokens):
    """One calibration batch: linear inputs + their per-policy block scores."""
    _, _, inputs = model_mod.forward(cfg, params, tokens, return_inputs=True)
    msq = [jnp.mean(h * h, axis=0) for h in inputs]
    return inputs, msq


def calibrate_model(name: str, out_dir: str, batches: int = 16, batch: int = 8,
                    seq: int = 128, seed: int = 7) -> dict:
    cfg = model_mod.FAMILIES[name]
    mdir = os.path.join(out_dir, name)
    params = {k: jnp.asarray(v) for k, v in tensorio.load(os.path.join(mdir, "weights.fgtn")).items()}
    corpus = data_mod.TinyCorpus()
    train_stream, _, _ = corpus.splits()
    gen = data_mod.batches(train_stream, batch, seq, seed=seed)
    linears = cfg.linears()
    nl = len(linears)

    t0 = time.time()
    fisher_w_acc: dict[str, np.ndarray] = {}
    act_fisher_acc = [np.zeros(k, np.float64) for (_, _, _, k, _) in linears]
    msq_acc = [np.zeros(k, np.float64) for (_, _, _, k, _) in linears]
    # Raw per-block scores per linear per policy (for the quantile tables).
    scores: dict[str, list[list[np.ndarray]]] = {p: [[] for _ in range(nl)] for p in POLICIES}

    # OE policy weighting for activations: mean over output channels of W^2
    # for the corresponding input channel (static, from the weights).
    oe_w = [np.asarray(jnp.mean(params[nm + ".w"] ** 2, axis=1)) for (nm, _, _, _, _) in linears]

    for bi in range(batches):
        tokens = jnp.asarray(next(gen))
        fw, af = _grad_step(cfg, params, tokens)
        for k, v in fw.items():
            fisher_w_acc[k] = fisher_w_acc.get(k, 0) + np.asarray(v, np.float64)
        for i in range(nl):
            act_fisher_acc[i] += np.asarray(af[i], np.float64)
        inputs, msq = _capture_step(cfg, params, tokens)
        for i in range(nl):
            msq_acc[i] += np.asarray(msq[i], np.float64)
        # Block impact scores under each weighting (subsample rows to bound
        # memory; deterministic stride keeps this reproducible).
        for i, h in enumerate(inputs):
            h = np.asarray(h)[:: max(1, len(inputs[i]) // 256)]
            hj = jnp.asarray(h)
            k = h.shape[1]
            w_fisher = jnp.asarray(act_fisher_acc[i] / (bi + 1), jnp.float32)
            for pol, cw in (("fisher", w_fisher),
                            ("qe", jnp.ones(k, jnp.float32)),
                            ("oe", jnp.asarray(oe_w[i], jnp.float32))):
                sc = np.asarray(ref.block_impact(hj, cw)).ravel()
                scores[pol][i].append(sc)

    n = float(batches)
    out_tensors: dict[str, np.ndarray] = {}
    fisher_w = {k: (v / n).astype(np.float32) for k, v in fisher_w_acc.items()}
    tensorio.save(os.path.join(mdir, "fisher_w.fgtn"), fisher_w)

    act_fisher = {linears[i][0]: (act_fisher_acc[i] / n).astype(np.float32) for i in range(nl)}
    tensorio.save(os.path.join(mdir, "act_fisher.fgtn"), act_fisher)
    act_msq = {linears[i][0]: (msq_acc[i] / n).astype(np.float32) for i in range(nl)}
    tensorio.save(os.path.join(mdir, "act_msq.fgtn"), act_msq)

    for pol in POLICIES:
        all_sc = np.concatenate([np.concatenate(scores[pol][i]) for i in range(nl)])
        out_tensors[f"{pol}.global"] = np.quantile(all_sc, QUANTS).astype(np.float32)
        local = np.stack(
            [np.quantile(np.concatenate(scores[pol][i]), QUANTS) for i in range(nl)]
        ).astype(np.float32)
        out_tensors[f"{pol}.local"] = local
    tensorio.save(os.path.join(mdir, "act_score_quantiles.fgtn"), out_tensors)

    wall = time.time() - t0
    meta = {"name": name, "batches": batches, "batch": batch, "seq": seq,
            "calib_tokens": batches * batch * seq, "seconds": wall}
    with open(os.path.join(mdir, "calibrate_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[{name}] calibration done in {wall:.1f}s "
          f"({batches * batch * seq} tokens)", flush=True)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = list(model_mod.FAMILIES) if args.model == "all" else [args.model]
    for nm in names:
        calibrate_model(nm, args.out, batches=args.batches)


if __name__ == "__main__":
    main()
