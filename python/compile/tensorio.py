"""FGTN tensor-container format: the python->rust artifact interchange.

Layout (little-endian):
    magic   b"FGTN"
    u32     version (1)
    u32     tensor count
    per tensor:
        u16     name length, then utf-8 name bytes
        u8      dtype (0 = f32, 1 = i32, 2 = u8)
        u8      ndim
        u64*    dims
        bytes   row-major payload

The Rust reader/writer lives in rust/src/io/tensorfile.rs; the two must stay
in lock-step (enforced by the round-trip integration test, which reads a
python-written file from Rust and re-writes it byte-identically).
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"FGTN"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write an ordered dict of arrays; iteration order is preserved."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                elif np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    raise TypeError(f"unsupported dtype {arr.dtype} for {name}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    """Read a file written by save() (or by the Rust writer)."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims).copy()
    return out
