"""Pallas kernels vs the pure-jnp oracle: the core L1 correctness signal.

Hypothesis sweeps shapes/magnitudes/thresholds; every comparison is exact
(same math, same rounding) except the matmul accumulation which gets a loose
float tolerance.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fgmp_matmul import fgmp_matmul, fgmp_quant_tile
from compile.kernels.fp8 import fp8_quant
from compile.kernels.nvfp4 import nvfp4_quant

SHAPES = st.sampled_from([(16, 16), (32, 32), (64, 16), (128, 64), (256, 48), (8, 96)])
SCALES = st.sampled_from([0.01, 0.3, 1.0, 4.0, 50.0, 400.0])


def _mk(shape, scale, seed):
    rs = np.random.RandomState(seed)
    return (rs.randn(*shape) * scale).astype(np.float32)


@given(shape=SHAPES, scale=SCALES, seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_nvfp4_kernel_matches_ref(shape, scale, seed):
    x = _mk(shape, scale, seed)
    got = np.asarray(nvfp4_quant(jnp.asarray(x), tile_m=shape[0]))
    want = np.asarray(ref.quant_nvfp4(jnp.asarray(x))[0])
    np.testing.assert_array_equal(got, want)


@given(shape=SHAPES, scale=SCALES, seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_fp8_kernel_matches_ref(shape, scale, seed):
    x = _mk(shape, scale, seed)
    got = np.asarray(fp8_quant(jnp.asarray(x), tile_m=shape[0]))
    want = np.asarray(ref.quant_e4m3(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


def test_nvfp4_kernel_tiled_equals_untiled():
    x = _mk((256, 64), 2.0, 3)
    a = np.asarray(nvfp4_quant(jnp.asarray(x), tile_m=32))
    b = np.asarray(nvfp4_quant(jnp.asarray(x), tile_m=256))
    np.testing.assert_array_equal(a, b)


@given(
    m=st.sampled_from([64, 128, 256]),
    k=st.sampled_from([32, 64, 96]),
    n=st.sampled_from([32, 128]),
    thr=st.sampled_from([-1.0, 0.005, 0.05, 0.5, 1e30]),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_fgmp_matmul_matches_ref(m, k, n, thr, seed):
    rs = np.random.RandomState(seed)
    x = (rs.randn(m, k) * 2).astype(np.float32)
    w = rs.randn(k, n).astype(np.float32)
    wq = np.asarray(ref.quant_nvfp4(jnp.asarray(w.T))[0]).T  # blocks along K
    cw = np.abs(rs.randn(k)).astype(np.float32)
    y, frac = fgmp_matmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(cw),
                          jnp.float32(thr), tile_m=64, tile_n=min(n, 128))
    yr, fr = ref.fgmp_matmul_ref(jnp.asarray(x), jnp.asarray(wq),
                                 jnp.asarray(cw), thr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-4)
    assert abs(float(frac) - float(fr)) < 1e-6


def test_fgmp_matmul_fraction_monotone_in_threshold():
    """Raising the threshold can only move blocks FP8 -> FP4."""
    rs = np.random.RandomState(9)
    x = (rs.randn(128, 64) * 2).astype(np.float32)
    w = np.asarray(ref.quant_nvfp4(jnp.asarray(rs.randn(128, 64)))[0]).T
    cw = jnp.ones(64)
    fracs = []
    for t in [0.0, 0.01, 0.1, 1.0, 10.0]:
        _, f = fgmp_matmul(jnp.asarray(x), jnp.asarray(w), cw, jnp.float32(t),
                           tile_m=128, tile_n=128)
        fracs.append(float(f))
    assert all(a >= b - 1e-9 for a, b in zip(fracs, fracs[1:]))


def test_fgmp_quant_tile_all_fp8_is_e4m3():
    x = jnp.asarray(_mk((32, 32), 3.0, 4))
    xq, keep = fgmp_quant_tile(x, jnp.ones(32), jnp.float32(-1.0))
    assert bool(jnp.all(keep))
    np.testing.assert_array_equal(np.asarray(xq), np.asarray(ref.quant_e4m3(x)))


def test_fgmp_quant_tile_all_fp4_is_nvfp4():
    x = jnp.asarray(_mk((32, 32), 3.0, 5))
    xq, keep = fgmp_quant_tile(x, jnp.ones(32), jnp.float32(1e30))
    assert not bool(jnp.any(keep))
    np.testing.assert_array_equal(np.asarray(xq), np.asarray(ref.quant_nvfp4(x)[0]))
