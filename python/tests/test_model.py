"""L2 model: shapes, family variants, quant-path consistency, NLL mechanics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def _toks(b, s, vocab=512, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(0, vocab, (b, s)), jnp.int32)


@pytest.mark.parametrize("name", list(M.FAMILIES))
def test_forward_shapes(name):
    cfg = M.FAMILIES[name]
    p = M.init_params(cfg, seed=1)
    toks = _toks(2, 32, cfg.vocab)
    logits, fracs = M.forward(cfg, p, toks)
    assert logits.shape == (2, 32, cfg.vocab)
    assert fracs.shape == (len(cfg.linears()),)


@pytest.mark.parametrize("name", list(M.FAMILIES))
def test_init_loss_near_uniform(name):
    cfg = M.FAMILIES[name]
    p = M.init_params(cfg, seed=2)
    loss = float(M.mean_loss(cfg, p, _toks(2, 32, cfg.vocab)))
    assert abs(loss - np.log(cfg.vocab)) < 0.35


def test_linear_inventory_consistent():
    for cfg in M.FAMILIES.values():
        lin = cfg.linears()
        assert len(lin) == 4 * cfg.n_layers
        kinds = [k for (_, _, k, _, _) in lin[:4]]
        assert kinds == list(M.LINEAR_KINDS)
        for (_, _, _, k_in, n_out) in lin:
            assert k_in % 16 == 0 and n_out % 16 == 0, "FGMP blocks must tile K"
        # param shapes agree with inventory
        for (nm, _, _, k_in, n_out) in lin:
            assert cfg.param_shape(nm + ".w") == (k_in, n_out)


def test_quant_ref_path_equals_pallas_path():
    cfg = M.FAMILIES["tiny-llama"]
    p = M.init_params(cfg, seed=3)
    toks = _toks(2, 128, cfg.vocab, seed=3)
    mask = jnp.ones(toks.shape, jnp.float32)
    nl = len(cfg.linears())
    aw = [jnp.ones(k) for (_, _, _, k, _) in cfg.linears()]
    th = jnp.full((nl,), 0.02)
    s1, n1, f1 = M.nll(cfg, p, toks, mask, linear_fn=M.LinearFn.FGMP_REF,
                       act_weights=aw, thresholds=th)
    s2, n2, f2 = M.nll(cfg, p, toks, mask, linear_fn=M.LinearFn.FGMP_PALLAS,
                       act_weights=aw, thresholds=th)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5, atol=1e-7)


def test_all_fp8_close_to_plain():
    """All-FP8 quantization should barely move the loss on a tiny model."""
    cfg = M.FAMILIES["tiny-llama"]
    p = M.init_params(cfg, seed=4)
    toks = _toks(2, 64, cfg.vocab, seed=4)
    nl = len(cfg.linears())
    aw = [jnp.ones(k) for (_, _, _, k, _) in cfg.linears()]
    plain = float(M.mean_loss(cfg, p, toks))
    fp8 = float(M.mean_loss(cfg, p, toks, linear_fn=M.LinearFn.FGMP_REF,
                            act_weights=aw, thresholds=jnp.full((nl,), -1.0)))
    assert abs(fp8 - plain) < 0.05


def test_fp4_worse_or_equal_fp8():
    cfg = M.FAMILIES["tiny-llama"]
    p = M.init_params(cfg, seed=5)
    toks = _toks(2, 64, cfg.vocab, seed=5)
    nl = len(cfg.linears())
    aw = [jnp.ones(k) for (_, _, _, k, _) in cfg.linears()]
    kw = dict(linear_fn=M.LinearFn.FGMP_REF, act_weights=aw)
    plain = float(M.mean_loss(cfg, p, toks))
    fp8 = float(M.mean_loss(cfg, p, toks, thresholds=jnp.full((nl,), -1.0), **kw))
    fp4 = float(M.mean_loss(cfg, p, toks, thresholds=jnp.full((nl,), 1e30), **kw))
    assert abs(fp8 - plain) < abs(fp4 - plain) + 0.05


def test_nll_masking():
    cfg = M.FAMILIES["tiny-llama"]
    p = M.init_params(cfg, seed=6)
    toks = _toks(2, 32, cfg.vocab, seed=6)
    full = jnp.ones(toks.shape, jnp.float32)
    half = full.at[:, : toks.shape[1] // 2].set(0.0)
    s_full, n_full, _ = M.nll(cfg, p, toks, full)
    s_half, n_half, _ = M.nll(cfg, p, toks, half)
    assert float(n_half.sum()) < float(n_full.sum())
    assert np.all(np.asarray(s_half) <= np.asarray(s_full) + 1e-4)


def test_return_inputs_matches_linear_count():
    cfg = M.FAMILIES["tiny-gpt"]
    p = M.init_params(cfg, seed=7)
    toks = _toks(2, 16, cfg.vocab, seed=7)
    _, _, inputs = M.forward(cfg, p, toks, return_inputs=True)
    lin = cfg.linears()
    assert len(inputs) == len(lin)
    for h, (_, _, _, k, _) in zip(inputs, lin):
        assert h.shape == (2 * 16, k)


def test_act_taps_gradient_is_activation_gradient():
    """Gradient w.r.t. a zero tap equals dLoss/d(linear input)."""
    cfg = M.FAMILIES["tiny-llama"]
    p = M.init_params(cfg, seed=8)
    toks = _toks(1, 16, cfg.vocab, seed=8)
    taps = [jnp.zeros((16, k), jnp.float32) for (_, _, _, k, _) in cfg.linears()]

    g = jax.grad(lambda t: M.mean_loss(cfg, p, toks, act_taps=t))(taps)
    assert len(g) == len(cfg.linears())
    assert all(float(jnp.sum(jnp.abs(x))) > 0 for x in g)


def test_deterministic_forward():
    cfg = M.FAMILIES["tiny-nemotron"]
    p = M.init_params(cfg, seed=9)
    toks = _toks(2, 16, cfg.vocab, seed=9)
    a, _ = M.forward(cfg, p, toks)
    b, _ = M.forward(cfg, p, toks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
