"""Build-pipeline integration: a micro train + calibrate run end-to-end
into a temp dir, validating every artifact the Rust side consumes."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import calibrate, model as M, tensorio, train


@pytest.fixture(scope="module")
def built():
    """Train tiny-gpt for a handful of steps and calibrate on 2 batches."""
    d = tempfile.mkdtemp(prefix="fgmp_pipe_")
    meta = train.train_model("tiny-gpt", d, steps=6, batch=4, seq=32, log_every=6)
    cmeta = calibrate.calibrate_model("tiny-gpt", d, batches=2, batch=2, seq=64)
    return d, meta, cmeta


def test_training_reduces_loss(built):
    _, meta, _ = built
    curve = meta["loss_curve"]
    assert curve[-1] < curve[0] + 0.1, "loss should not explode in 6 steps"
    assert meta["n_params"] > 100_000


def test_weights_artifact_complete(built):
    d, _, _ = built
    cfg = M.FAMILIES["tiny-gpt"]
    w = tensorio.load(os.path.join(d, "tiny-gpt", "weights.fgtn"))
    # jax pytrees sort dict keys, so on-disk order is alphabetical; consumers
    # (rust Evaluator, aot.py) index by *manifest* order by name — only the
    # name set must match.
    assert set(w) == set(cfg.param_names())
    for name in cfg.param_names():
        assert w[name].shape == cfg.param_shape(name)
        assert np.isfinite(w[name]).all()


def test_fisher_artifacts(built):
    d, _, _ = built
    cfg = M.FAMILIES["tiny-gpt"]
    fw = tensorio.load(os.path.join(d, "tiny-gpt", "fisher_w.fgtn"))
    af = tensorio.load(os.path.join(d, "tiny-gpt", "act_fisher.fgtn"))
    msq = tensorio.load(os.path.join(d, "tiny-gpt", "act_msq.fgtn"))
    for (nm, _, _, k, n) in cfg.linears():
        f = fw[f"{nm}.w.fisher"]
        assert f.shape == (k, n)
        assert (f >= 0).all() and f.max() > 0, "squared grads: nonneg, not all-zero"
        assert af[nm].shape == (k,) and (af[nm] >= 0).all()
        assert msq[nm].shape == (k,) and (msq[nm] >= 0).all()


def test_quantile_tables_monotone(built):
    d, _, _ = built
    cfg = M.FAMILIES["tiny-gpt"]
    q = tensorio.load(os.path.join(d, "tiny-gpt", "act_score_quantiles.fgtn"))
    nl = len(cfg.linears())
    for pol in ("fisher", "qe", "oe"):
        g = q[f"{pol}.global"]
        assert g.shape == (99,)
        assert (np.diff(g) >= -1e-12).all(), f"{pol} global quantiles monotone"
        assert (g >= 0).all()
        loc = q[f"{pol}.local"]
        assert loc.shape == (nl, 99)
        assert (np.diff(loc, axis=1) >= -1e-12).all()


def test_calibrate_meta_recorded(built):
    d, _, cmeta = built
    assert cmeta["calib_tokens"] == 2 * 2 * 64
    with open(os.path.join(d, "tiny-gpt", "calibrate_meta.json")) as f:
        on_disk = json.load(f)
    assert on_disk["seconds"] > 0
