"""Corpus determinism, cloze-suite sanity, and FGTN container round-trips."""

import os
import tempfile

import numpy as np
import pytest

from compile import data as D
from compile import tensorio


class TestCorpus:
    def test_deterministic(self):
        c1, c2 = D.TinyCorpus(seed=1234), D.TinyCorpus(seed=1234)
        np.testing.assert_array_equal(c1.sample(2000, 5), c2.sample(2000, 5))

    def test_seed_changes_stream(self):
        c = D.TinyCorpus()
        assert not np.array_equal(c.sample(2000, 1), c.sample(2000, 2))

    def test_token_range(self):
        s = D.TinyCorpus().sample(5000, 1)
        assert s.min() >= 0 and s.max() < D.VOCAB

    def test_zipf_head_is_heavy(self):
        """Frequent tokens dominate: top-32 tokens cover > 35% of the stream."""
        s = D.TinyCorpus().sample(50_000, 9)
        s = s[s != D.BOS]
        counts = np.bincount(s, minlength=D.VOCAB)
        top = np.sort(counts)[::-1][:32].sum()
        assert top / counts.sum() > 0.35

    def test_splits_disjoint_seeds(self):
        tr, va, te = D.TinyCorpus().splits(train=4096, valid=4096, test=4096)
        assert not np.array_equal(tr[:4096], va)
        assert not np.array_equal(va, te)

    def test_markov_structure_learnable(self):
        """Bigram model on train beats unigram on held-out (there IS signal)."""
        c = D.TinyCorpus()
        tr, va, _ = c.splits(train=200_000, valid=20_000, test=1)
        big = np.ones((D.VOCAB, D.VOCAB))
        np.add.at(big, (tr[:-1], tr[1:]), 1)
        big /= big.sum(1, keepdims=True)
        uni = np.bincount(tr, minlength=D.VOCAB) + 1.0
        uni /= uni.sum()
        nll_b = -np.mean(np.log(big[va[:-1], va[1:]]))
        nll_u = -np.mean(np.log(uni[va[1:]]))
        assert nll_b < nll_u - 0.5


class TestBatches:
    def test_shapes_and_determinism(self):
        s = D.TinyCorpus().sample(10_000, 1)
        g1 = D.batches(s, 4, 32, seed=3)
        g2 = D.batches(s, 4, 32, seed=3)
        a, b = next(g1), next(g2)
        assert a.shape == (4, 32) and a.dtype == np.int32
        np.testing.assert_array_equal(a, b)

    def test_eval_windows_cover_nonoverlapping(self):
        s = np.arange(1000, dtype=np.int32)
        wins = list(D.eval_windows(s, 2, 100))
        flat = np.concatenate([w.ravel() for w in wins])
        assert len(flat) == len(np.unique(flat))  # no overlap


class TestCloze:
    def test_suite_structure(self):
        c = D.TinyCorpus()
        _, _, te = c.splits(train=1, valid=1, test=30_000)
        items = D.make_cloze_suite(c, te, n_items=16, ctx_len=24, cont_len=8,
                                   hard=True, seed=5)
        assert len(items) == 16
        for it in items:
            assert len(it["context"]) == 24
            assert len(it["options"]) == 4
            assert all(len(o) == 8 for o in it["options"])
            assert 0 <= it["answer"] < 4

    def test_answers_not_constant(self):
        c = D.TinyCorpus()
        _, _, te = c.splits(train=1, valid=1, test=30_000)
        items = D.make_cloze_suite(c, te, n_items=64, ctx_len=16, cont_len=4,
                                   hard=False, seed=6)
        assert len({it["answer"] for it in items}) == 4  # shuffled placement


class TestTensorIO:
    def test_roundtrip(self):
        rs = np.random.RandomState(0)
        tensors = {
            "a": rs.randn(3, 4).astype(np.float32),
            "b": rs.randint(-5, 5, (7,)).astype(np.int32),
            "c": (rs.rand(2, 2, 2) * 255).astype(np.uint8),
            "scalarish": np.float32([3.5]),
        }
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.fgtn")
            tensorio.save(p, tensors)
            back = tensorio.load(p)
        assert list(back) == list(tensors)  # order preserved
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_f64_downcast(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.fgtn")
            tensorio.save(p, {"x": np.array([1.0, 2.0])})
            assert tensorio.load(p)["x"].dtype == np.float32

    def test_bad_magic_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "bad.fgtn")
            with open(p, "wb") as f:
                f.write(b"NOPE" + b"\x00" * 16)
            with pytest.raises(ValueError):
                tensorio.load(p)
