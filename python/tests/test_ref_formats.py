"""Properties of the reference number-format round-trips (the numerics spec)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

E2M1_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)
E2M1_FULL = np.unique(np.concatenate([E2M1_GRID, -E2M1_GRID]))


def e4m3_grid():
    """All non-negative finite E4M3 values, constructed from first principles."""
    vals = [0.0]
    for e in range(-6, 9):
        for m in range(8):
            vals.append((1 + m / 8) * 2.0**e)
    for m in range(1, 8):
        vals.append(m / 8 * 2.0**-6)  # subnormals
    return np.unique(np.array([v for v in vals if v <= 448.0], np.float32))


E4M3_GRID = e4m3_grid()


class TestE2M1:
    def test_grid_is_fixed_point(self):
        q = np.asarray(ref.quant_e2m1(jnp.asarray(E2M1_FULL)))
        np.testing.assert_array_equal(q, E2M1_FULL)

    def test_saturates(self):
        q = np.asarray(ref.quant_e2m1(jnp.asarray([100.0, -100.0, 6.01, 7.0])))
        np.testing.assert_array_equal(q, [6.0, -6.0, 6.0, 6.0])

    def test_outputs_on_grid(self):
        x = np.random.RandomState(0).randn(4096).astype(np.float32) * 4
        q = np.asarray(ref.quant_e2m1(jnp.asarray(x)))
        assert np.all(np.isin(q, E2M1_FULL))

    def test_nearest(self):
        """Every output is the nearest grid point (up to tie-breaking)."""
        x = np.random.RandomState(1).randn(4096).astype(np.float32) * 4
        q = np.asarray(ref.quant_e2m1(jnp.asarray(x)))
        xc = np.clip(x, -6, 6)
        best = E2M1_FULL[np.argmin(np.abs(xc[:, None] - E2M1_FULL[None, :]), axis=1)]
        err_q = np.abs(q - xc)
        err_b = np.abs(best - xc)
        np.testing.assert_allclose(err_q, err_b, atol=1e-7)

    def test_ties_to_even_mantissa(self):
        # 1.75 is midway between 1.5 (odd mantissa) and 2.0 (even): -> 2.0
        # 1.25 is midway between 1.0 (even) and 1.5 (odd): -> 1.0
        q = np.asarray(ref.quant_e2m1(jnp.asarray([1.75, 1.25, 0.25, 0.75, 2.5, 3.5, 5.0])))
        np.testing.assert_array_equal(q, [2.0, 1.0, 0.0, 1.0, 2.0, 4.0, 4.0])

    @given(st.floats(-1e4, 1e4, allow_nan=False, width=32))
    @settings(max_examples=200, deadline=None)
    def test_idempotent(self, v):
        q1 = float(ref.quant_e2m1(jnp.float32(v)))
        q2 = float(ref.quant_e2m1(jnp.float32(q1)))
        assert q1 == q2

    @given(st.floats(0, 6, width=32))
    @settings(max_examples=100, deadline=None)
    def test_sign_symmetry(self, v):
        assert float(ref.quant_e2m1(jnp.float32(-v))) == -float(
            ref.quant_e2m1(jnp.float32(v))
        )


class TestE4M3:
    def test_grid_is_fixed_point(self):
        full = np.unique(np.concatenate([E4M3_GRID, -E4M3_GRID]))
        q = np.asarray(ref.quant_e4m3(jnp.asarray(full)))
        np.testing.assert_array_equal(q, full)

    def test_saturates(self):
        q = np.asarray(ref.quant_e4m3(jnp.asarray([1e9, -1e9, 449.0])))
        np.testing.assert_array_equal(q, [448.0, -448.0, 448.0])

    def test_outputs_on_grid(self):
        x = (np.random.RandomState(2).randn(4096) * 50).astype(np.float32)
        q = np.asarray(ref.quant_e4m3(jnp.asarray(x)))
        full = np.unique(np.concatenate([E4M3_GRID, -E4M3_GRID]))
        assert np.all(np.isin(q, full))

    def test_nearest(self):
        x = (np.random.RandomState(3).randn(2048) * 10).astype(np.float32)
        q = np.asarray(ref.quant_e4m3(jnp.asarray(x)))
        xc = np.clip(x, -448, 448)
        full = np.unique(np.concatenate([E4M3_GRID, -E4M3_GRID]))
        best = full[np.argmin(np.abs(xc[:, None] - full[None, :]), axis=1)]
        np.testing.assert_allclose(np.abs(q - xc), np.abs(best - xc), rtol=1e-6, atol=1e-9)

    def test_subnormals(self):
        q = np.asarray(ref.quant_e4m3(jnp.asarray([2.0**-9, 2.0**-9 * 0.49, 2.0**-10])))
        np.testing.assert_array_equal(q, [2.0**-9, 0.0, 0.0])

    @given(st.floats(-1e6, 1e6, allow_nan=False, width=32))
    @settings(max_examples=200, deadline=None)
    def test_idempotent(self, v):
        q1 = float(ref.quant_e4m3(jnp.float32(v)))
        assert float(ref.quant_e4m3(jnp.float32(q1))) == q1

    def test_relative_error_bound(self):
        """Normal-range quantization error <= 2^-4 relative (3 mantissa bits)."""
        x = np.abs(np.random.RandomState(4).randn(4096).astype(np.float32)) + 0.1
        q = np.asarray(ref.quant_e4m3(jnp.asarray(x)))
        assert np.max(np.abs(q - x) / x) <= 2.0**-4 + 1e-6


class TestNVFP4:
    def test_scale_never_overflows_grid(self):
        """With dynamic-max scaling, |x/scale| stays within ~E2M1 range."""
        rs = np.random.RandomState(5)
        x = (rs.randn(64, 32) * np.exp(rs.randn(64, 1) * 3)).astype(np.float32)
        q, scale = ref.quant_nvfp4(jnp.asarray(x))
        q = np.asarray(q)
        scale = np.asarray(scale)
        # every dequantized magnitude <= 6 * scale of its block
        qb = q.reshape(64, 2, 16)
        assert np.all(np.abs(qb) <= 6 * scale[..., None] + 1e-6)

    def test_zero_block(self):
        q, scale = ref.quant_nvfp4(jnp.zeros((1, 16)))
        assert float(jnp.sum(jnp.abs(q))) == 0.0

    def test_blockwise_independence(self):
        """Changing one block never affects another block's output."""
        rs = np.random.RandomState(6)
        x = rs.randn(2, 32).astype(np.float32)
        q1, _ = ref.quant_nvfp4(jnp.asarray(x))
        x2 = x.copy()
        x2[:, 16:] *= 100
        q2, _ = ref.quant_nvfp4(jnp.asarray(x2))
        np.testing.assert_array_equal(np.asarray(q1)[:, :16], np.asarray(q2)[:, :16])

    def test_explicit_scale_roundtrip(self):
        rs = np.random.RandomState(7)
        x = rs.randn(4, 16).astype(np.float32)
        _, s_dyn = ref.quant_nvfp4(jnp.asarray(x))
        q, s_used = ref.quant_nvfp4(jnp.asarray(x), scale=s_dyn)
        q_dyn, _ = ref.quant_nvfp4(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_dyn))

    def test_microscaling_beats_global_fp4(self):
        """Per-block scaling must reduce MSE vs one global scale (the reason
        microscaling exists; paper SS2.1)."""
        rs = np.random.RandomState(8)
        x = (rs.randn(256, 64) * np.exp(rs.randn(256, 1) * 2)).astype(np.float32)
        q_block, _ = ref.quant_nvfp4(jnp.asarray(x))
        gscale = np.abs(x).max() / 6.0
        q_glob = np.asarray(ref.quant_e2m1(jnp.asarray(x / gscale))) * gscale
        mse_block = float(np.mean((np.asarray(q_block) - x) ** 2))
        mse_glob = float(np.mean((q_glob - x) ** 2))
        assert mse_block < mse_glob


class TestImpactScore:
    def test_nonnegative_and_zero_for_identical(self):
        rs = np.random.RandomState(9)
        x = rs.randn(8, 64).astype(np.float32)
        cw = np.abs(rs.randn(64)).astype(np.float32)
        s = np.asarray(ref.block_impact(jnp.asarray(x), jnp.asarray(cw)))
        assert np.all(s >= 0)

    def test_weighting_scales_score(self):
        """Doubling every channel weight doubles every score (linearity)."""
        rs = np.random.RandomState(10)
        x = rs.randn(8, 64).astype(np.float32) * 3
        cw = np.abs(rs.randn(64)).astype(np.float32)
        s1 = np.asarray(ref.block_impact(jnp.asarray(x), jnp.asarray(cw)))
        s2 = np.asarray(ref.block_impact(jnp.asarray(x), jnp.asarray(cw * 2)))
        np.testing.assert_allclose(s2, 2 * s1, rtol=1e-5)

    def test_threshold_extremes(self):
        rs = np.random.RandomState(11)
        x = (rs.randn(32, 64) * 2).astype(np.float32)
        cw = jnp.ones(64)
        _, keep_hi = ref.fgmp_quant(jnp.asarray(x), cw, jnp.float32(-1.0))
        _, keep_lo = ref.fgmp_quant(jnp.asarray(x), cw, jnp.float32(1e30))
        assert bool(jnp.all(keep_hi)) and not bool(jnp.any(keep_lo))

    def test_mixed_equals_select(self):
        """FGMP output blocks equal the corresponding single-format round-trip."""
        rs = np.random.RandomState(12)
        x = (rs.randn(16, 64) * 2).astype(np.float32)
        cw = jnp.ones(64)
        t = 0.05
        xq, keep = ref.fgmp_quant(jnp.asarray(x), cw, jnp.float32(t))
        q4, _ = ref.quant_nvfp4(jnp.asarray(x))
        q8 = ref.quant_fp8_block(jnp.asarray(x))
        xqb = np.asarray(xq).reshape(16, 4, 16)
        q4b = np.asarray(q4).reshape(16, 4, 16)
        q8b = np.asarray(q8).reshape(16, 4, 16)
        keep = np.asarray(keep)
        for i in range(16):
            for j in range(4):
                expect = q8b[i, j] if keep[i, j] else q4b[i, j]
                np.testing.assert_array_equal(xqb[i, j], expect)
